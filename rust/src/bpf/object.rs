//! BPF object container — the on-disk unit a policy compiles to and the
//! hot-reload mechanism swaps in (the role ELF `.o` files play for
//! bpftime/libbpf).
//!
//! An object bundles map *declarations*, one or more programs keyed by
//! section name (`tuner` / `profiler` / `net`, as in `SEC("tuner")`),
//! and relocations binding `lddw rX, map[...]` instructions to maps *by
//! name*. Map name resolution happens at load time against a shared
//! [`MapRegistry`](super::maps::MapRegistry), which is what lets two
//! independently deployed objects (a profiler and a tuner) share a map.
//!
//! Binary layout (all little-endian):
//! ```text
//!   "BEF1" | u32 nmaps  | MapDef*        (strings are u16 len + bytes)
//!          | u32 nprogs | Program*
//!   Program: section str | name str | u32 ninsn | insn bytes
//!            | u32 nreloc | { u32 insn_idx, map name str }*
//! ```

use super::helpers::ProgType;
use super::insn::{self, Insn};
use super::maps::{MapDef, MapKind};

const MAGIC: &[u8; 4] = b"BEF1";

/// A map reference relocation: instruction `insn_idx` is the first slot
/// of an `lddw` whose imm must be patched with the live id of `map_name`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Reloc {
    /// index of the first lddw slot to patch
    pub insn_idx: u32,
    /// map name resolved against the registry at load time
    pub map_name: String,
}

/// One program section within an object.
#[derive(Clone, Debug, PartialEq)]
pub struct ObjProgram {
    /// section name (`tuner` / `profiler` / `net`)
    pub section: String,
    /// program name (unique within the object)
    pub name: String,
    /// the instruction stream (subprograms inline after the main body)
    pub insns: Vec<Insn>,
    /// map-reference relocations
    pub relocs: Vec<Reloc>,
}

impl ObjProgram {
    /// The program type implied by the section name, if recognized.
    pub fn prog_type(&self) -> Option<ProgType> {
        ProgType::from_section(&self.section)
    }
}

/// A complete BPF object: maps + programs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Object {
    /// map declarations (resolved by name at load time)
    pub maps: Vec<MapDef>,
    /// program sections
    pub progs: Vec<ObjProgram>,
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    out.extend_from_slice(&(b.len() as u16).to_le_bytes());
    out.extend_from_slice(b);
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "truncated object: need {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String, String> {
        let n = self.u16()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| "invalid utf8 string in object".to_string())
    }
}

impl Object {
    /// Find a map declaration by name.
    pub fn map(&self, name: &str) -> Option<&MapDef> {
        self.maps.iter().find(|m| m.name == name)
    }

    /// Find a program by name.
    pub fn prog(&self, name: &str) -> Option<&ObjProgram> {
        self.progs.iter().find(|p| p.name == name)
    }

    /// Find the first program in `section`.
    pub fn prog_by_section(&self, section: &str) -> Option<&ObjProgram> {
        self.progs.iter().find(|p| p.section == section)
    }

    /// Total instruction count across every program in the object (the
    /// size figure `ncclbpf verify --stats` reports next to the
    /// verifier's insns-processed counters).
    pub fn total_insns(&self) -> usize {
        self.progs.iter().map(|p| p.insns.len()).sum()
    }

    /// Serialize to the binary container format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.maps.len() as u32).to_le_bytes());
        for m in &self.maps {
            put_str(&mut out, &m.name);
            out.extend_from_slice(&m.kind.to_u32().to_le_bytes());
            out.extend_from_slice(&m.key_size.to_le_bytes());
            out.extend_from_slice(&m.value_size.to_le_bytes());
            out.extend_from_slice(&m.max_entries.to_le_bytes());
        }
        out.extend_from_slice(&(self.progs.len() as u32).to_le_bytes());
        for p in &self.progs {
            put_str(&mut out, &p.section);
            put_str(&mut out, &p.name);
            out.extend_from_slice(&(p.insns.len() as u32).to_le_bytes());
            out.extend_from_slice(&insn::encode_program(&p.insns));
            out.extend_from_slice(&(p.relocs.len() as u32).to_le_bytes());
            for r in &p.relocs {
                out.extend_from_slice(&r.insn_idx.to_le_bytes());
                put_str(&mut out, &r.map_name);
            }
        }
        out
    }

    /// Parse the binary container format.
    pub fn from_bytes(buf: &[u8]) -> Result<Object, String> {
        let mut r = Reader { buf, pos: 0 };
        if r.take(4)? != MAGIC {
            return Err("bad magic: not a BEF1 bpf object".to_string());
        }
        let nmaps = r.u32()? as usize;
        if nmaps > 1024 {
            return Err(format!("implausible map count {}", nmaps));
        }
        let mut maps = Vec::with_capacity(nmaps);
        for _ in 0..nmaps {
            let name = r.str()?;
            let kind = MapKind::from_u32(r.u32()?).ok_or("unknown map kind")?;
            let key_size = r.u32()?;
            let value_size = r.u32()?;
            let max_entries = r.u32()?;
            let def = MapDef { name, kind, key_size, value_size, max_entries };
            def.validate()?;
            maps.push(def);
        }
        let nprogs = r.u32()? as usize;
        if nprogs > 256 {
            return Err(format!("implausible program count {}", nprogs));
        }
        let mut progs = Vec::with_capacity(nprogs);
        for _ in 0..nprogs {
            let section = r.str()?;
            let name = r.str()?;
            let ninsn = r.u32()? as usize;
            if ninsn > 1 << 20 {
                return Err(format!("implausible insn count {}", ninsn));
            }
            let bytes = r.take(ninsn * 8)?;
            let insns = insn::decode_program(bytes)?;
            let nreloc = r.u32()? as usize;
            let mut relocs = Vec::with_capacity(nreloc);
            for _ in 0..nreloc {
                let insn_idx = r.u32()?;
                let map_name = r.str()?;
                if insn_idx as usize >= insns.len() {
                    return Err(format!("reloc target {} out of range", insn_idx));
                }
                relocs.push(Reloc { insn_idx, map_name });
            }
            progs.push(ObjProgram { section, name, insns, relocs });
        }
        if r.pos != buf.len() {
            return Err(format!("trailing garbage: {} bytes", buf.len() - r.pos));
        }
        Ok(Object { maps, progs })
    }

    /// Serialize to a `.bpfo` file.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_bytes())
    }

    /// Read and parse a `.bpfo` file.
    pub fn load(path: &std::path::Path) -> Result<Object, String> {
        let bytes =
            std::fs::read(path).map_err(|e| format!("read {}: {}", path.display(), e))?;
        Object::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpf::insn::*;

    fn sample() -> Object {
        let mut insns = vec![];
        insns.extend(ld_map_fd(1, 0)); // imm patched at load; reloc below
        insns.push(mov64_imm(0, 0));
        insns.push(exit());
        Object {
            maps: vec![MapDef {
                name: "latency_map".into(),
                kind: MapKind::Array,
                key_size: 4,
                value_size: 16,
                max_entries: 64,
            }],
            progs: vec![ObjProgram {
                section: "tuner".into(),
                name: "size_aware".into(),
                insns,
                relocs: vec![Reloc { insn_idx: 0, map_name: "latency_map".into() }],
            }],
        }
    }

    #[test]
    fn roundtrip() {
        let o = sample();
        let bytes = o.to_bytes();
        let back = Object::from_bytes(&bytes).unwrap();
        assert_eq!(o, back);
    }

    #[test]
    fn bad_magic() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(Object::from_bytes(&bytes).unwrap_err().contains("magic"));
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample().to_bytes();
        for cut in [3, 10, bytes.len() - 1] {
            assert!(Object::from_bytes(&bytes[..cut]).is_err(), "cut at {}", cut);
        }
    }

    #[test]
    fn trailing_garbage_detected() {
        let mut bytes = sample().to_bytes();
        bytes.push(0);
        assert!(Object::from_bytes(&bytes).unwrap_err().contains("trailing"));
    }

    #[test]
    fn reloc_out_of_range() {
        let mut o = sample();
        o.progs[0].relocs[0].insn_idx = 99;
        let bytes = o.to_bytes();
        assert!(Object::from_bytes(&bytes).unwrap_err().contains("out of range"));
    }

    #[test]
    fn accessors() {
        let o = sample();
        assert!(o.map("latency_map").is_some());
        assert!(o.map("nope").is_none());
        assert_eq!(o.total_insns(), 4); // lddw (2 slots) + mov + exit
        assert_eq!(o.prog("size_aware").unwrap().section, "tuner");
        assert!(o.prog_by_section("tuner").is_some());
        assert_eq!(
            o.prog("size_aware").unwrap().prog_type(),
            Some(crate::bpf::helpers::ProgType::Tuner)
        );
    }

    #[test]
    fn file_roundtrip() {
        let o = sample();
        let dir = std::env::temp_dir().join("ncclbpf_obj_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bpfo");
        o.save(&p).unwrap();
        let back = Object::load(&p).unwrap();
        assert_eq!(o, back);
    }
}
