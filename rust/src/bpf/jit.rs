//! Native x86-64 JIT for verified programs.
//!
//! Mirrors bpftime's LLVM JIT role (§4): after verification, programs
//! are compiled to machine code so the per-decision dispatch cost
//! approaches native ("the LLVM JIT produces optimized x86-64 code,
//! narrowing the gap to native performance"). Table 1's bench reports
//! the interp-vs-JIT ablation; EXPERIMENTS.md §Perf has before/after.
//!
//! Register mapping (the kernel's x86 BPF JIT convention, adapted):
//!
//! ```text
//!   BPF r0..r10 → rax rdi rsi rdx rcx r8 rbx r13 r14 r15 rbp
//!   r12         → &HelperEnv (callee-saved, never a BPF register)
//!   r11         → scratch
//! ```
//!
//! Calling convention: `fn(ctx: *mut u8, env: *const HelperEnv) -> u64`
//! (SysV: ctx arrives in rdi — which *is* BPF r1 — and env in rsi,
//! parked in r12 by the prologue). Helper calls shuffle r1–r5 into the
//! per-helper trampoline's SysV argument slots; r1–r5 live in
//! caller-saved registers so the clobber the verifier models is exactly
//! what the hardware does.
//!
//! Bpf-to-bpf calls compile to native near calls: every pseudo-call
//! target gets a per-subprog prologue that saves the caller's BPF
//! r6–r9 and frame pointer (exactly the machine-preservation contract
//! the verifier models) and carves a private 512-byte frame, so
//! `call rel32` / `ret` do the rest. `bpf_tail_call` goes through a
//! two-word trampoline returning (r0, taken) in rax:rdx — on a taken
//! call the chained program already ran and the emitted code exits
//! through the epilogue without resuming the caller.
//!
//! Any op the backend cannot compile aborts compilation and the program
//! falls back to the pre-decoded interpreter — correctness never
//! depends on the JIT (both engines only ever run verified code).
//!
//! **Verifier-informed inlining** ([`JitOptions`]): when the load path
//! hands the per-op fact table from verification to
//! [`JitProgram::compile_with`], helper-call sites the verifier proved
//! safe are
//! specialized — a constant-key `Array` lookup becomes an immediate
//! address, a bounded-key lookup a load+scale with the index check
//! elided, ringbuf submit/discard a handful of inline stores, and the
//! remaining whitelisted helpers direct calls into per-helper entry
//! points that skip the dispatch trampoline and argument shuffle.
//! Every site without a proving fact keeps the generic trampoline,
//! and `JitOptions::inline` (driven by `NCCLBPF_JIT_INLINE` at the
//! CLI edge) turns the whole tier off, so the differential nets can
//! pin interp == JIT-trampoline == JIT-inlined. Soundness argument:
//! DESIGN.md §11 — facts are consequences of accepted verification,
//! so the specialized code is refinement-equivalent to the trampoline
//! path it replaces.

use super::helpers::{id as hid, HelperEnv};
use super::insn::{alu, atomic, jmp, size};
use super::interp::{Op, MAX_TAIL_CALLS, TAIL_DEPTH};
use super::maps::{Map, MapKind, RINGBUF_DISCARD_BIT, RINGBUF_HDR_SIZE, RINGBUF_LEN_MASK};
use super::program::resolve_tail_call;
use super::verifier::InsnFacts;
use std::sync::Arc;

/// Raw libc bindings for executable-memory management. The `libc`
/// crate is not available offline, and these three symbols are part of
/// every POSIX libc the binary already links against.
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const PROT_WRITE: i32 = 2;
    pub const PROT_EXEC: i32 = 4;
    pub const MAP_PRIVATE: i32 = 0x02;
    #[cfg(target_os = "linux")]
    pub const MAP_ANONYMOUS: i32 = 0x20;
    #[cfg(not(target_os = "linux"))]
    pub const MAP_ANONYMOUS: i32 = 0x1000; // BSD/macOS value
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn mprotect(addr: *mut c_void, len: usize, prot: i32) -> i32;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

// x86-64 register numbers
const RAX: u8 = 0;
const RCX: u8 = 1;
const RDX: u8 = 2;
const RBX: u8 = 3;
const RSP: u8 = 4;
const RBP: u8 = 5;
const RSI: u8 = 6;
const RDI: u8 = 7;
const R8: u8 = 8;
const R9: u8 = 9;
const R10: u8 = 10;
const R11: u8 = 11;
const R12: u8 = 12;
const R13: u8 = 13;
const R14: u8 = 14;
const R15: u8 = 15;

/// BPF register → x86 register.
const REGMAP: [u8; 11] = [RAX, RDI, RSI, RDX, RCX, R8, RBX, R13, R14, R15, RBP];

const STACK_BYTES: i32 = 512;
/// sub rsp, 520: 6 pushes (48) + ret addr (8) = 56 ≡ 8 (mod 16); +520 → 0.
const FRAME: i32 = STACK_BYTES + 8;

// -- helper trampolines -------------------------------------------------------

macro_rules! tramp {
    ($name:ident, $id:expr) => {
        unsafe extern "C" fn $name(
            env: *const HelperEnv,
            a1: u64,
            a2: u64,
            a3: u64,
            a4: u64,
            a5: u64,
        ) -> u64 {
            (*env).call($id, [a1, a2, a3, a4, a5])
        }
    };
}

tramp!(tramp_lookup, hid::MAP_LOOKUP_ELEM);
tramp!(tramp_update, hid::MAP_UPDATE_ELEM);
tramp!(tramp_delete, hid::MAP_DELETE_ELEM);
tramp!(tramp_ktime, hid::KTIME_GET_NS);
tramp!(tramp_printk, hid::TRACE_PRINTK);
tramp!(tramp_prandom, hid::GET_PRANDOM_U32);
tramp!(tramp_cpuid, hid::GET_SMP_PROCESSOR_ID);
tramp!(tramp_rb_output, hid::RINGBUF_OUTPUT);
tramp!(tramp_rb_reserve, hid::RINGBUF_RESERVE);
tramp!(tramp_rb_submit, hid::RINGBUF_SUBMIT);
tramp!(tramp_rb_discard, hid::RINGBUF_DISCARD);
tramp!(tramp_rb_query, hid::RINGBUF_QUERY);

/// Two-word return of the tail-call trampoline: SysV returns the pair
/// in rax:rdx, so the emitted code can test `taken` without reaching
/// into Rust thread-locals — rax already holds the final r0.
#[repr(C)]
struct TailRet {
    r0: u64,
    taken: u64,
}

/// `bpf_tail_call` for JIT'd programs. On success the chained program
/// runs to completion *here* and the emitted code jumps straight to
/// the epilogue with our r0 — the caller never resumes, observably
/// identical to the kernel's in-place jump (the target cannot read the
/// dying frame: init-before-read is verified per program). The chain
/// limit is shared with the interpreter through [`TAIL_DEPTH`], so
/// mixed-engine chains count as one chain.
unsafe extern "C" fn tramp_tail_call(
    env: *const HelperEnv,
    ctx: u64,
    map_id: u64,
    index: u64,
    _a4: u64,
    _a5: u64,
) -> TailRet {
    let depth = TAIL_DEPTH.with(|d| d.get());
    if depth >= MAX_TAIL_CALLS {
        if let Some(cell) = &(*env).stats {
            cell.record_error();
        }
        return TailRet { r0: u64::MAX, taken: 0 };
    }
    let Some(target) = resolve_tail_call(&*env, map_id as u32, index) else {
        if let Some(cell) = &(*env).stats {
            cell.record_error();
        }
        return TailRet { r0: u64::MAX, taken: 0 };
    };
    TAIL_DEPTH.with(|d| d.set(depth + 1));
    // kernel-style attribution: the dispatch counts against the
    // initiator; the target runs untracked (a taken tail call is not a
    // fresh top-level entry), matching the interpreter's in-place switch
    if let Some(cell) = &(*env).stats {
        cell.record_tail_call(depth + 1);
    }
    let r0 = target.run_untracked(ctx as *mut u8);
    TAIL_DEPTH.with(|d| d.set(depth));
    TailRet { r0, taken: 1 }
}

fn trampoline(helper: i32) -> Option<u64> {
    let f: unsafe extern "C" fn(*const HelperEnv, u64, u64, u64, u64, u64) -> u64 =
        match helper {
            hid::MAP_LOOKUP_ELEM => tramp_lookup,
            hid::MAP_UPDATE_ELEM => tramp_update,
            hid::MAP_DELETE_ELEM => tramp_delete,
            hid::KTIME_GET_NS => tramp_ktime,
            hid::TRACE_PRINTK => tramp_printk,
            hid::GET_PRANDOM_U32 => tramp_prandom,
            hid::GET_SMP_PROCESSOR_ID => tramp_cpuid,
            hid::RINGBUF_OUTPUT => tramp_rb_output,
            hid::RINGBUF_RESERVE => tramp_rb_reserve,
            hid::RINGBUF_SUBMIT => tramp_rb_submit,
            hid::RINGBUF_DISCARD => tramp_rb_discard,
            hid::RINGBUF_QUERY => tramp_rb_query,
            _ => return None,
        };
    Some(f as usize as u64)
}

// -- direct-call entry points -------------------------------------------------
//
// At a BPF helper-call site r1–r5 already sit in rdi rsi rdx rcx r8 —
// exactly the SysV argument slots — so once the verifier has proved
// which map a site touches, a specialized entry point taking the BPF
// arguments *directly* needs only `mov rdi, <map ptr>` emitted ahead
// of the call: no argument shuffle, no helper-id dispatch, no linear
// map scan. Each body replicates the corresponding `HelperEnv::call`
// arm bit-for-bit (same slice sizes, same return codes) so the
// differential net can hold inlined == trampoline == interpreter.
// The embedded `*const Map` stays valid because the emitted code is
// owned by a `LoadedProgram` that also owns the `HelperEnv` (and its
// `Arc<Map>`s) it was compiled against.

unsafe extern "C" fn drct_lookup(m: *const Map, key: *const u8) -> u64 {
    let m = &*m;
    let key = std::slice::from_raw_parts(key, m.def.key_size as usize);
    m.lookup(key) as u64
}

unsafe extern "C" fn drct_update(m: *const Map, key: *const u8, val: *const u8) -> u64 {
    let m = &*m;
    let key = std::slice::from_raw_parts(key, m.def.key_size as usize);
    let val = std::slice::from_raw_parts(val, m.def.value_size as usize);
    match m.update(key, val) {
        Ok(()) => 0,
        Err(_) => (-1i64) as u64,
    }
}

unsafe extern "C" fn drct_delete(m: *const Map, key: *const u8) -> u64 {
    let m = &*m;
    let key = std::slice::from_raw_parts(key, m.def.key_size as usize);
    match m.delete(key) {
        Ok(true) => 0,
        _ => (-1i64) as u64,
    }
}

unsafe extern "C" fn drct_rb_reserve(m: *const Map, size: u64) -> u64 {
    (*m).ringbuf_reserve(size) as u64
}

unsafe extern "C" fn drct_rb_output(m: *const Map, data: *const u8, len: u64) -> u64 {
    let bytes = std::slice::from_raw_parts(data, len as usize);
    (*m).ringbuf_output(bytes) as u64
}

unsafe extern "C" fn drct_rb_query(m: *const Map, flag: u64) -> u64 {
    (*m).ringbuf_query(flag)
}

unsafe extern "C" fn drct_ktime() -> u64 {
    super::helpers::ktime_get_ns()
}

unsafe extern "C" fn drct_prandom() -> u64 {
    super::helpers::prandom_u32() as u64
}

unsafe extern "C" fn drct_cpuid() -> u64 {
    Map::current_cpu() as u64
}

/// Codegen options for [`JitProgram::compile_with`].
#[derive(Clone, Copy, Default)]
pub struct JitOptions<'a> {
    /// Per-op verifier fact table (op-indexed — raw slot-indexed facts
    /// from [`super::verifier::VerifyInfo`] must first go through
    /// [`super::interp::remap_facts`]). `None` disables specialization.
    pub facts: Option<&'a [InsnFacts]>,
    /// Helper environment the program will run against, used to
    /// resolve map ids to live map pointers at compile time. Inlined
    /// code embeds those pointers, so the program must only ever run
    /// against this environment (the load path guarantees it:
    /// `LoadedProgram` owns both).
    pub env: Option<&'a HelperEnv>,
    /// Tri-state inlining toggle: `None` means on whenever `facts`
    /// and `env` are both present; `Some(false)` forces every call
    /// site through the generic trampoline (the `NCCLBPF_JIT_INLINE=0`
    /// path, threaded from the CLI edge).
    pub inline: Option<bool>,
}

/// Per-site codegen decisions made while compiling one program —
/// the JIT-side mirror of the verifier's `inline_candidates` /
/// `bounds_elided` counters, reported by `BENCH_inline.json`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JitInlineStats {
    /// `Array` lookups compiled to base+offset address computation.
    pub inlined_lookups: u64,
    /// Ringbuf submit/discard sites compiled to inline header stores.
    pub inlined_ringbuf: u64,
    /// Helper sites compiled to direct calls into specialized entry
    /// points (per-cpu/hash lookups, updates, reserve, output, ...).
    pub direct_calls: u64,
    /// Array index checks elided because the verifier bounded the key
    /// below `max_entries`.
    pub bounds_elided: u64,
    /// Call sites that kept the generic dispatch trampoline.
    pub trampoline_calls: u64,
}

// -- emitter -------------------------------------------------------------------

struct Emit {
    code: Vec<u8>,
}

impl Emit {
    fn new() -> Emit {
        Emit { code: Vec::with_capacity(1024) }
    }
    fn u8(&mut self, b: u8) {
        self.code.push(b);
    }
    fn u32(&mut self, v: u32) {
        self.code.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.code.extend_from_slice(&v.to_le_bytes());
    }

    /// REX prefix. w: 64-bit, r: modrm.reg ext, b: modrm.rm/base ext.
    fn rex(&mut self, w: bool, r: u8, b: u8) {
        let v = 0x40
            | (w as u8) << 3
            | ((r >> 3) & 1) << 2
            | ((b >> 3) & 1);
        if v != 0x40 || w {
            self.u8(v);
        } else {
            // REX.40 needed for sil/dil in byte ops; harmless elsewhere.
            self.u8(0x40);
        }
    }

    fn modrm(&mut self, md: u8, reg: u8, rm: u8) {
        self.u8(md << 6 | (reg & 7) << 3 | (rm & 7));
    }

    /// modrm for [base + disp32]; base is never rsp/r12 in our mapping.
    fn mem(&mut self, reg: u8, base: u8, disp: i32) {
        debug_assert!(base & 7 != RSP);
        self.modrm(0b10, reg, base);
        self.u32(disp as u32);
    }

    // mov dst, src (64-bit)
    fn mov_rr(&mut self, dst: u8, src: u8) {
        self.rex(true, src, dst);
        self.u8(0x89);
        self.modrm(0b11, src, dst);
    }
    // mov dst32, src32 (zero-extends)
    fn mov_rr32(&mut self, dst: u8, src: u8) {
        self.rex(false, src, dst);
        self.u8(0x89);
        self.modrm(0b11, src, dst);
    }
    // mov dst, imm64 / sign-extended imm32
    fn mov_imm(&mut self, dst: u8, v: i64) {
        if v >= i32::MIN as i64 && v <= i32::MAX as i64 {
            self.rex(true, 0, dst);
            self.u8(0xc7);
            self.modrm(0b11, 0, dst);
            self.u32(v as u32);
        } else {
            self.rex(true, 0, dst);
            self.u8(0xb8 + (dst & 7));
            self.u64(v as u64);
        }
    }
    // mov dst32, imm32 (zero-extends)
    fn mov_imm32(&mut self, dst: u8, v: u32) {
        self.rex(false, 0, dst);
        self.u8(0xc7);
        self.modrm(0b11, 0, dst);
        self.u32(v);
    }
    // ALU r/m64 op= r64 (opcode form 0x01/0x29/...)
    fn alu_rr(&mut self, opcode: u8, dst: u8, src: u8, w: bool) {
        self.rex(w, src, dst);
        self.u8(opcode);
        self.modrm(0b11, src, dst);
    }
    // ALU r/m64 op= imm32 (81 /n)
    fn alu_imm(&mut self, ext: u8, dst: u8, v: i32, w: bool) {
        self.rex(w, 0, dst);
        self.u8(0x81);
        self.modrm(0b11, ext, dst);
        self.u32(v as u32);
    }
    // imul dst, src
    fn imul_rr(&mut self, dst: u8, src: u8, w: bool) {
        self.rex(w, dst, src);
        self.u8(0x0f);
        self.u8(0xaf);
        self.modrm(0b11, dst, src);
    }
    fn push(&mut self, r: u8) {
        if r >= 8 {
            self.u8(0x41);
        }
        self.u8(0x50 + (r & 7));
    }
    fn pop(&mut self, r: u8) {
        if r >= 8 {
            self.u8(0x41);
        }
        self.u8(0x58 + (r & 7));
    }
}

/// Shuffle BPF r1..r5 (rdi rsi rdx rcx r8) into SysV args 2..6, env
/// (r12) into arg 1 — reverse order so nothing is clobbered early —
/// then call the trampoline at `target` through r11.
fn emit_call_shuffle(e: &mut Emit, target: u64) {
    e.mov_rr(R9, R8); // a5
    e.mov_rr(R8, RCX); // a4
    e.mov_rr(RCX, RDX); // a3
    e.mov_rr(RDX, RSI); // a2
    e.mov_rr(RSI, RDI); // a1
    e.mov_rr(RDI, R12); // env
    e.mov_imm(R11, target as i64);
    // call r11
    e.u8(0x41);
    e.u8(0xff);
    e.modrm(0b11, 2, R11);
}

/// Direct near call to a specialized entry point: BPF r1–r5 already
/// sit in the SysV argument slots, so only the resolved map pointer
/// (when the target takes one) needs to be materialized into arg 1.
fn emit_direct_call(e: &mut Emit, map: Option<u64>, target: u64) {
    if let Some(p) = map {
        e.mov_imm(RDI, p as i64);
    }
    e.mov_imm(R11, target as i64);
    // call r11
    e.u8(0x41);
    e.u8(0xff);
    e.modrm(0b11, 2, R11);
}

/// The `and`/`or`/`xor` atomics (fetch and fetchless) have no
/// single-instruction x86 lowering that also observes the old value
/// atomically, so they compile to the kernel-JIT cmpxchg retry loop:
/// observe, compute the new value in a scratch register, `lock
/// cmpxchg`, retry if another thread won the race. `r9`/`r10`/`r11`
/// are scratch; BPF r0 (`rax`, the implicit cmpxchg comparand) is
/// saved and restored around the loop unless the op fetches into it.
fn emit_atomic_loop(e: &mut Emit, aop: i32, d: u8, s: u8, off: i16, w: bool) -> Option<()> {
    let opcode = match aop & !atomic::FETCH {
        atomic::AND => 0x21,
        atomic::OR => 0x09,
        atomic::XOR => 0x31,
        _ => return None,
    };
    let fetch = aop & atomic::FETCH != 0;
    e.push(RAX); // save BPF r0: the loop owns rax
    e.mov_rr(R9, d); // base pointer (d may be rax)
    e.mov_rr(R10, s); // value operand (s may be rax)
    // mov (e)ax, [r9 + off] — the initial observation
    e.rex(w, RAX, R9);
    e.u8(0x8b);
    e.mem(RAX, R9, off as i32);
    let retry = e.code.len();
    if w {
        e.mov_rr(R11, RAX);
    } else {
        e.mov_rr32(R11, RAX);
    }
    e.alu_rr(opcode, R11, R10, w); // r11 = old OP operand
    // lock cmpxchg [r9 + off], r11 — succeeds iff memory still holds
    // rax; on failure rax receives the value that beat us
    e.u8(0xf0);
    e.rex(w, R11, R9);
    e.u8(0x0f);
    e.u8(0xb1);
    e.mem(R11, R9, off as i32);
    // jne retry (rel8 — the loop body is ~20 bytes)
    e.u8(0x75);
    let rel = retry as i64 - (e.code.len() as i64 + 1);
    e.u8(rel as i8 as u8);
    // rax now holds the pre-op value (32-bit forms zero-extended by
    // the 32-bit load / cmpxchg writeback)
    if fetch {
        if s == RAX {
            // the fetch destination IS r0: keep the old value in rax
            // and drop the saved copy (add rsp, 8)
            e.alu_imm(0, RSP, 8, true);
        } else {
            if w {
                e.mov_rr(s, RAX);
            } else {
                e.mov_rr32(s, RAX);
            }
            e.pop(RAX);
        }
    } else {
        e.pop(RAX);
    }
    Some(())
}

/// Inline `bpf_ringbuf_submit`/`discard`: the record header is the
/// u32 at `data - 8`; committing is one release store of the length
/// with the busy bit clear (plus the discard bit for discard) — on
/// x86-64 a plain 32-bit mov *is* a release store, so the whole
/// helper is four instructions and r0 = 0, exactly what
/// `Map::ringbuf_submit`/`ringbuf_discard` do.
fn emit_ringbuf_release(e: &mut Emit, discard: bool) {
    let hdr_off = -(RINGBUF_HDR_SIZE as i32);
    // mov r11d, [rdi + hdr_off]
    e.rex(false, R11, RDI);
    e.u8(0x8b);
    e.mem(R11, RDI, hdr_off);
    // and r11d, LEN_MASK (clears busy + discard bits)
    e.rex(false, 0, R11);
    e.u8(0x81);
    e.modrm(0b11, 4, R11);
    e.u32(RINGBUF_LEN_MASK);
    if discard {
        // or r11d, DISCARD_BIT
        e.rex(false, 0, R11);
        e.u8(0x81);
        e.modrm(0b11, 1, R11);
        e.u32(RINGBUF_DISCARD_BIT);
    }
    // mov [rdi + hdr_off], r11d — the committing release store
    e.rex(false, R11, RDI);
    e.u8(0x89);
    e.mem(R11, RDI, hdr_off);
    // xor eax, eax — the helper returns 0
    e.alu_rr(0x31, RAX, RAX, false);
}

/// Inline an `Array` lookup at a site where the verifier proved the
/// map constant. Three tiers, cheapest first: constant key → the
/// element address is a single immediate (index check discharged at
/// verification time); key bounded below `max_entries` → load + scale
/// with the index check elided; key bounded but not below capacity →
/// load + check + scale (still no dispatch). Returns false when no
/// key fact exists — the caller falls back to a direct call or the
/// trampoline, which is the "non-constant map index" fallback the
/// test suite pins.
fn emit_array_lookup(e: &mut Emit, m: &Map, f: &InsnFacts, stats: &mut JitInlineStats) -> bool {
    let base = m.value_base_ptr() as u64;
    let vsize = m.def.value_size as u64;
    let n = m.def.max_entries as u64;
    if vsize == 0 || vsize > i32::MAX as u64 {
        return false;
    }
    if let Some(k) = f.const_key {
        if k < n {
            e.mov_imm(RAX, (base + k * vsize) as i64);
        } else {
            // constant out-of-range index: lookup is statically null
            e.alu_rr(0x31, RAX, RAX, false);
        }
        stats.inlined_lookups += 1;
        stats.bounds_elided += 1;
        return true;
    }
    let Some(umax) = f.key_umax else { return false };
    // mov eax, dword [rsi] — the verified 4-byte key behind BPF r2
    e.rex(false, RAX, RSI);
    e.u8(0x8b);
    e.mem(RAX, RSI, 0);
    let mut done_patch = None;
    if umax >= n {
        // cmp eax, max_entries; jb .in; xor eax, eax; jmp .done; .in:
        e.alu_imm(7, RAX, m.def.max_entries as i32, false);
        e.u8(0x72); // jb rel8
        let jb = e.code.len();
        e.u8(0);
        e.alu_rr(0x31, RAX, RAX, false);
        e.u8(0xeb); // jmp rel8
        let jmp = e.code.len();
        e.u8(0);
        let in_off = e.code.len();
        e.code[jb] = (in_off - (jb + 1)) as u8;
        done_patch = Some(jmp);
    } else {
        stats.bounds_elided += 1;
    }
    // imul rax, rax, value_size
    e.rex(true, RAX, RAX);
    e.u8(0x69);
    e.modrm(0b11, RAX, RAX);
    e.u32(vsize as u32);
    e.mov_imm(R11, base as i64);
    e.alu_rr(0x01, RAX, R11, true); // add rax, r11
    if let Some(jmp) = done_patch {
        let done = e.code.len();
        e.code[jmp] = (done - (jmp + 1)) as u8;
    }
    stats.inlined_lookups += 1;
    true
}

/// Emit specialized code for one helper-call site using the
/// verifier's facts. Returns false when no sound specialization
/// applies — the caller keeps the generic trampoline. Every arm is
/// guarded on `f.direct_call` (the verifier's "argument types permit
/// a direct call on every path" proof), so a site reached with
/// conflicting maps or a released ringbuf record never specializes.
fn emit_inline_call(
    e: &mut Emit,
    helper: i32,
    f: &InsnFacts,
    env: &HelperEnv,
    stats: &mut JitInlineStats,
) -> bool {
    if !f.direct_call {
        return false;
    }
    let map = f.map_id.and_then(|id| env.map_by_id(id));
    let map_ptr = map.map(|m| Arc::as_ptr(m) as u64);
    match helper {
        hid::RINGBUF_SUBMIT | hid::RINGBUF_DISCARD => {
            emit_ringbuf_release(e, helper == hid::RINGBUF_DISCARD);
            stats.inlined_ringbuf += 1;
            true
        }
        hid::KTIME_GET_NS => {
            emit_direct_call(e, None, drct_ktime as usize as u64);
            stats.direct_calls += 1;
            true
        }
        hid::GET_PRANDOM_U32 => {
            emit_direct_call(e, None, drct_prandom as usize as u64);
            stats.direct_calls += 1;
            true
        }
        hid::GET_SMP_PROCESSOR_ID => {
            emit_direct_call(e, None, drct_cpuid as usize as u64);
            stats.direct_calls += 1;
            true
        }
        hid::MAP_LOOKUP_ELEM => {
            let Some(m) = map else { return false };
            if m.def.kind == MapKind::Array && emit_array_lookup(e, m, f, stats) {
                return true;
            }
            match m.def.kind {
                // per-cpu lookups resolve the pinned cpu slot (a
                // thread-local read) inside the entry point — a direct
                // call, not pure address arithmetic; hash lookups probe
                MapKind::Array | MapKind::PerCpuArray | MapKind::Hash => {
                    emit_direct_call(e, map_ptr, drct_lookup as usize as u64);
                    stats.direct_calls += 1;
                    true
                }
                _ => false,
            }
        }
        hid::MAP_UPDATE_ELEM => {
            if map.is_none() {
                return false;
            }
            emit_direct_call(e, map_ptr, drct_update as usize as u64);
            stats.direct_calls += 1;
            true
        }
        hid::MAP_DELETE_ELEM => {
            if map.is_none() {
                return false;
            }
            emit_direct_call(e, map_ptr, drct_delete as usize as u64);
            stats.direct_calls += 1;
            true
        }
        hid::RINGBUF_RESERVE => {
            let Some(m) = map else { return false };
            if m.def.kind != MapKind::RingBuf {
                return false;
            }
            // the entry point is the slow path too: reservation takes
            // the ring lock and handles wrap, so "fast path" here means
            // skipping dispatch + map scan + shuffle, not the lock
            emit_direct_call(e, map_ptr, drct_rb_reserve as usize as u64);
            stats.direct_calls += 1;
            true
        }
        hid::RINGBUF_OUTPUT => {
            let Some(m) = map else { return false };
            if m.def.kind != MapKind::RingBuf {
                return false;
            }
            emit_direct_call(e, map_ptr, drct_rb_output as usize as u64);
            stats.direct_calls += 1;
            true
        }
        hid::RINGBUF_QUERY => {
            let Some(m) = map else { return false };
            if m.def.kind != MapKind::RingBuf {
                return false;
            }
            emit_direct_call(e, map_ptr, drct_rb_query as usize as u64);
            stats.direct_calls += 1;
            true
        }
        _ => false,
    }
}

/// Tear down the main frame: add rsp, FRAME; pop callee-saved; ret.
fn emit_main_epilogue(e: &mut Emit) {
    e.alu_imm(0, RSP, FRAME, true);
    for r in [RBP, R15, R14, R13, R12, RBX] {
        e.pop(r);
    }
    e.u8(0xc3);
}

/// Subprogram prologue: save the caller's BPF r10 (rbp) and r6-r9
/// (rbx r13 r14 r15) — bpf-to-bpf calls preserve exactly what the
/// verifier models as preserved — then carve a fresh full-size stack
/// frame (the verifier's cumulative cap bounds live usage; a private
/// 512-byte frame per subprog only over-provides). Entry rsp is
/// 8 mod 16 after the near call; 5 pushes + the 16-aligned frame put
/// helper-call sites back on 16-byte alignment.
fn emit_subprog_prologue(e: &mut Emit) {
    for r in [RBP, RBX, R13, R14, R15] {
        e.push(r);
    }
    // sub rsp, 512
    e.alu_imm(5, RSP, STACK_BYTES, true);
    // lea rbp, [rsp + 512] — BPF r10 = frame top
    e.rex(true, RBP, RSP);
    e.u8(0x8d);
    e.modrm(0b10, RBP, RSP);
    e.u8(0x24); // SIB: base=rsp
    e.u32(STACK_BYTES as u32);
}

/// Subprogram exit: unwind the frame and restore the caller's BPF
/// r6-r9 / r10; rax carries the scalar return (BPF r0).
fn emit_subprog_epilogue(e: &mut Emit) {
    e.alu_imm(0, RSP, STACK_BYTES, true);
    for r in [R15, R14, R13, RBX, RBP] {
        e.pop(r);
    }
    e.u8(0xc3);
}

/// A JIT-compiled program (owns executable memory).
pub struct JitProgram {
    code: *mut u8,
    len: usize,
    stats: JitInlineStats,
}

unsafe impl Send for JitProgram {}
unsafe impl Sync for JitProgram {}

impl Drop for JitProgram {
    fn drop(&mut self) {
        unsafe {
            sys::munmap(self.code as *mut std::ffi::c_void, self.len);
        }
    }
}

impl JitProgram {
    /// Attempt to compile; `None` falls back to the interpreter.
    /// Trampoline-only codegen — see [`JitProgram::compile_with`] for
    /// the verifier-informed inlining tier.
    pub fn compile(ops: &[Op]) -> Option<JitProgram> {
        Self::compile_with(ops, &JitOptions::default())
    }

    /// Attempt to compile with explicit [`JitOptions`]; `None` falls
    /// back to the interpreter.
    pub fn compile_with(ops: &[Op], opts: &JitOptions) -> Option<JitProgram> {
        if std::env::var_os("NCCLBPF_NO_JIT").is_some() {
            return None;
        }
        Self::compile_with_unchecked(ops, opts)
    }

    /// Compile regardless of the `NCCLBPF_NO_JIT` gate. Used by tests
    /// so they do not have to mutate process-global environment state
    /// (which would race with concurrently running tests).
    pub fn compile_unchecked(ops: &[Op]) -> Option<JitProgram> {
        Self::compile_with_unchecked(ops, &JitOptions::default())
    }

    /// [`JitProgram::compile_with`] without the `NCCLBPF_NO_JIT` gate.
    pub fn compile_with_unchecked(ops: &[Op], opts: &JitOptions) -> Option<JitProgram> {
        if !cfg!(all(unix, target_arch = "x86_64")) {
            // the emitter below produces x86-64 SysV code and the
            // executable mapping uses POSIX mmap; everything else
            // falls back to the pre-decoded interpreter
            return None;
        }
        // inlining needs a valid per-op fact table *and* the live maps
        // to resolve pointers against; anything less keeps every call
        // site on the generic trampoline
        let facts = match (opts.inline.unwrap_or(true), opts.env, opts.facts) {
            (true, Some(_), Some(f)) if f.len() == ops.len() => Some(f),
            _ => None,
        };
        let mut stats = JitInlineStats::default();
        let mut e = Emit::new();
        // prologue
        for r in [RBX, R12, R13, R14, R15, RBP] {
            e.push(r);
        }
        // sub rsp, FRAME
        e.alu_imm(5, RSP, FRAME, true);
        // lea rbp, [rsp + STACK_BYTES]
        e.rex(true, RBP, RSP);
        e.u8(0x8d);
        e.modrm(0b10, RBP, RSP);
        e.u8(0x24); // SIB: base=rsp
        e.u32(STACK_BYTES as u32);
        // mov r12, rsi (env)
        e.mov_rr(R12, RSI);
        // rdi already holds ctx == BPF r1

        // bpf-to-bpf layout: every pseudo-call target starts a
        // subprogram, emitted in place behind its own prologue (the
        // kernel-JIT shape: near calls between per-subprog functions,
        // each with its own frame and callee-saved spills).
        let mut entries: Vec<u32> = ops
            .iter()
            .filter_map(|op| match op {
                Op::CallPseudo { t } => Some(*t),
                _ => None,
            })
            .collect();
        entries.sort_unstable();
        entries.dedup();
        if entries.first() == Some(&0) {
            // a callable main never comes out of the verifier; fall
            // back to the interpreter rather than emit nonsense
            return None;
        }

        let mut op_off = vec![0u32; ops.len() + 1];
        let mut fixups: Vec<(usize, u32)> = Vec::new(); // (code pos of rel32, target op)
        // call sites bind to the *prologue*, branches to the entry op
        let mut prologue_off: Vec<(u32, u32)> = Vec::new();
        let mut call_fixups: Vec<(usize, u32)> = Vec::new();

        for (i, op) in ops.iter().enumerate() {
            if entries.binary_search(&(i as u32)).is_ok() {
                prologue_off.push((i as u32, e.code.len() as u32));
                emit_subprog_prologue(&mut e);
            }
            op_off[i] = e.code.len() as u32;
            let in_sub = entries.partition_point(|&en| (en as usize) <= i) > 0;
            match *op {
                Op::Alu64Imm { op, dst, imm } => emit_alu_imm(&mut e, op, dst, imm, true)?,
                Op::Alu32Imm { op, dst, imm } => emit_alu_imm(&mut e, op, dst, imm, false)?,
                Op::Alu64Reg { op, dst, src } => emit_alu_reg(&mut e, op, dst, src, true)?,
                Op::Alu32Reg { op, dst, src } => emit_alu_reg(&mut e, op, dst, src, false)?,
                Op::Neg64 { dst } => {
                    let d = REGMAP[dst as usize];
                    e.rex(true, 0, d);
                    e.u8(0xf7);
                    e.modrm(0b11, 3, d);
                }
                Op::Neg32 { dst } => {
                    let d = REGMAP[dst as usize];
                    e.rex(false, 0, d);
                    e.u8(0xf7);
                    e.modrm(0b11, 3, d);
                }
                Op::LoadImm64 { dst, imm } => e.mov_imm(REGMAP[dst as usize], imm as i64),
                Op::LoadMapFd { dst, map_id } => e.mov_imm32(REGMAP[dst as usize], map_id),
                Op::Load { width, dst, src, off } => {
                    let d = REGMAP[dst as usize];
                    let s = REGMAP[src as usize];
                    match width {
                        size::B => {
                            e.rex(false, d, s);
                            e.u8(0x0f);
                            e.u8(0xb6);
                            e.mem(d, s, off as i32);
                        }
                        size::H => {
                            e.rex(false, d, s);
                            e.u8(0x0f);
                            e.u8(0xb7);
                            e.mem(d, s, off as i32);
                        }
                        size::W => {
                            e.rex(false, d, s);
                            e.u8(0x8b);
                            e.mem(d, s, off as i32);
                        }
                        _ => {
                            e.rex(true, d, s);
                            e.u8(0x8b);
                            e.mem(d, s, off as i32);
                        }
                    }
                }
                Op::Store { width, dst, src, off } => {
                    let d = REGMAP[dst as usize];
                    let s = REGMAP[src as usize];
                    match width {
                        size::B => {
                            e.rex(false, s, d);
                            e.u8(0x88);
                            e.mem(s, d, off as i32);
                        }
                        size::H => {
                            e.u8(0x66);
                            e.rex(false, s, d);
                            e.u8(0x89);
                            e.mem(s, d, off as i32);
                        }
                        size::W => {
                            e.rex(false, s, d);
                            e.u8(0x89);
                            e.mem(s, d, off as i32);
                        }
                        _ => {
                            e.rex(true, s, d);
                            e.u8(0x89);
                            e.mem(s, d, off as i32);
                        }
                    }
                }
                Op::StoreImm { width, dst, off, imm } => {
                    let d = REGMAP[dst as usize];
                    match width {
                        size::B => {
                            e.rex(false, 0, d);
                            e.u8(0xc6);
                            e.mem(0, d, off as i32);
                            e.u8(imm as u8);
                        }
                        size::H => {
                            e.u8(0x66);
                            e.rex(false, 0, d);
                            e.u8(0xc7);
                            e.mem(0, d, off as i32);
                            e.code.extend_from_slice(&(imm as u16).to_le_bytes());
                        }
                        size::W => {
                            e.rex(false, 0, d);
                            e.u8(0xc7);
                            e.mem(0, d, off as i32);
                            e.u32(imm as u32);
                        }
                        _ => {
                            e.rex(true, 0, d);
                            e.u8(0xc7);
                            e.mem(0, d, off as i32);
                            e.u32(imm as u32); // sign-extended imm32
                        }
                    }
                }
                Op::Atomic { aop, dst, src, off, is64 } => {
                    let d = REGMAP[dst as usize];
                    let s = REGMAP[src as usize];
                    match aop {
                        x if x == atomic::ADD => {
                            // lock add [d + off], s
                            e.u8(0xf0);
                            e.rex(is64, s, d);
                            e.u8(0x01);
                            e.mem(s, d, off as i32);
                        }
                        x if x == atomic::ADD | atomic::FETCH => {
                            // lock xadd [d + off], s — s receives the
                            // old value (32-bit writes zero-extend)
                            e.u8(0xf0);
                            e.rex(is64, s, d);
                            e.u8(0x0f);
                            e.u8(0xc1);
                            e.mem(s, d, off as i32);
                        }
                        x if x == atomic::XCHG => {
                            // xchg with a memory operand is implicitly
                            // locked
                            e.rex(is64, s, d);
                            e.u8(0x87);
                            e.mem(s, d, off as i32);
                        }
                        x if x == atomic::CMPXCHG => {
                            // lock cmpxchg [d + off], s: rax IS BPF r0
                            // in our REGMAP, so the comparand and the
                            // observed-value destination need no
                            // shuffling. (dst == r0 cannot reach the
                            // JIT: the verifier requires a scalar r0.)
                            e.u8(0xf0);
                            e.rex(is64, s, d);
                            e.u8(0x0f);
                            e.u8(0xb1);
                            e.mem(s, d, off as i32);
                            if !is64 {
                                // the success path leaves eax
                                // unwritten — force the zero-extension
                                // the BPF ISA promises for 32-bit r0
                                e.mov_rr32(RAX, RAX);
                            }
                        }
                        _ => emit_atomic_loop(&mut e, aop, d, s, off, is64)?,
                    }
                }
                Op::Ja { t } => {
                    e.u8(0xe9);
                    fixups.push((e.code.len(), t));
                    e.u32(0);
                }
                Op::JmpImm { op, dst, imm, t, is32 } => {
                    let d = REGMAP[dst as usize];
                    if op == jmp::JSET {
                        // test d, imm32
                        e.rex(!is32, 0, d);
                        e.u8(0xf7);
                        e.modrm(0b11, 0, d);
                        e.u32(imm as u32);
                    } else {
                        e.alu_imm(7, d, imm as i32, !is32); // cmp
                    }
                    e.u8(0x0f);
                    e.u8(jcc(op)?);
                    fixups.push((e.code.len(), t));
                    e.u32(0);
                }
                Op::JmpReg { op, dst, src, t, is32 } => {
                    let d = REGMAP[dst as usize];
                    let s = REGMAP[src as usize];
                    if op == jmp::JSET {
                        e.alu_rr(0x85, d, s, !is32); // test d, s
                    } else {
                        e.alu_rr(0x39, d, s, !is32); // cmp d, s
                    }
                    e.u8(0x0f);
                    e.u8(jcc(op)?);
                    fixups.push((e.code.len(), t));
                    e.u32(0);
                }
                Op::Call { helper } if helper == hid::TAIL_CALL => {
                    // the verifier restricts tail calls to the main
                    // frame, so the taken path leaves through the main
                    // epilogue with rax = the chained program's r0
                    emit_call_shuffle(&mut e, tramp_tail_call as usize as u64);
                    // TailRet arrives in rax (r0) : rdx (taken)
                    e.alu_rr(0x85, RDX, RDX, true); // test rdx, rdx
                    e.u8(0x74); // jz rel8 over the epilogue (not taken)
                    let jz = e.code.len();
                    e.u8(0);
                    emit_main_epilogue(&mut e);
                    let end = e.code.len();
                    e.code[jz] = (end - (jz + 1)) as u8;
                }
                Op::Call { helper } => {
                    let mut inlined = false;
                    if let (Some(f), Some(env)) = (facts, opts.env) {
                        inlined = emit_inline_call(&mut e, helper, &f[i], env, &mut stats);
                    }
                    if !inlined {
                        let target = trampoline(helper)?;
                        emit_call_shuffle(&mut e, target);
                        stats.trampoline_calls += 1;
                    }
                }
                Op::CallPseudo { t } => {
                    // near call; the callee's prologue saves BPF r6-r9
                    // and rbp, so the machine preserves exactly what the
                    // verifier models as preserved
                    e.u8(0xe8);
                    call_fixups.push((e.code.len(), t));
                    e.u32(0);
                }
                Op::Exit => {
                    if in_sub {
                        emit_subprog_epilogue(&mut e);
                    } else {
                        emit_main_epilogue(&mut e);
                    }
                }
            }
        }
        op_off[ops.len()] = e.code.len() as u32;

        for (pos, target) in fixups {
            let rel = op_off[target as usize] as i64 - (pos as i64 + 4);
            e.code[pos..pos + 4].copy_from_slice(&(rel as i32).to_le_bytes());
        }
        for (pos, target) in call_fixups {
            let dst = prologue_off.iter().find(|&&(t, _)| t == target).map(|&(_, o)| o)?;
            let rel = dst as i64 - (pos as i64 + 4);
            e.code[pos..pos + 4].copy_from_slice(&(rel as i32).to_le_bytes());
        }

        // map executable memory
        let len = e.code.len().max(1);
        unsafe {
            let mem = sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_PRIVATE | sys::MAP_ANONYMOUS,
                -1,
                0,
            );
            if mem == sys::MAP_FAILED {
                return None;
            }
            std::ptr::copy_nonoverlapping(e.code.as_ptr(), mem as *mut u8, e.code.len());
            if sys::mprotect(mem, len, sys::PROT_READ | sys::PROT_EXEC) != 0 {
                sys::munmap(mem, len);
                return None;
            }
            Some(JitProgram { code: mem as *mut u8, len, stats })
        }
    }

    /// # Safety
    /// Same contract as [`super::interp::execute`]. Additionally, if
    /// the program was compiled with [`JitOptions::env`], the emitted
    /// code embeds raw pointers into that environment's maps — it must
    /// only be called while those maps are alive, and semantically
    /// `env` should be that same environment (the load path satisfies
    /// both: `LoadedProgram` owns the env its JIT was compiled with).
    #[inline]
    pub unsafe fn call(&self, ctx: *mut u8, env: &HelperEnv) -> u64 {
        let f: unsafe extern "C" fn(*mut u8, *const HelperEnv) -> u64 =
            std::mem::transmute(self.code);
        f(ctx, env as *const HelperEnv)
    }

    /// Bytes of emitted machine code (mapped length).
    pub fn code_len(&self) -> usize {
        self.len
    }

    /// Per-site codegen decisions made during compilation (all zero
    /// for trampoline-only compiles).
    pub fn inline_stats(&self) -> JitInlineStats {
        self.stats
    }
}

/// x86 condition code for a BPF jump op (second byte of 0F 8x).
fn jcc(op: u8) -> Option<u8> {
    Some(match op {
        jmp::JEQ => 0x84,
        jmp::JNE => 0x85,
        jmp::JGT => 0x87,  // ja
        jmp::JGE => 0x83,  // jae
        jmp::JLT => 0x82,  // jb
        jmp::JLE => 0x86,  // jbe
        jmp::JSGT => 0x8f, // jg
        jmp::JSGE => 0x8d, // jge
        jmp::JSLT => 0x8c, // jl
        jmp::JSLE => 0x8e, // jle
        jmp::JSET => 0x85, // jnz after test
        _ => return None,
    })
}

fn emit_alu_reg(e: &mut Emit, op: u8, dst: u8, src: u8, w: bool) -> Option<()> {
    let d = REGMAP[dst as usize];
    let s = REGMAP[src as usize];
    match op {
        alu::ADD => e.alu_rr(0x01, d, s, w),
        alu::SUB => e.alu_rr(0x29, d, s, w),
        alu::OR => e.alu_rr(0x09, d, s, w),
        alu::AND => e.alu_rr(0x21, d, s, w),
        alu::XOR => e.alu_rr(0x31, d, s, w),
        alu::MOV => {
            if w {
                e.mov_rr(d, s)
            } else {
                e.mov_rr32(d, s)
            }
        }
        alu::MUL => e.imul_rr(d, s, w),
        alu::DIV | alu::MOD => emit_divmod(e, d, s, op == alu::MOD, w),
        alu::LSH | alu::RSH | alu::ARSH => emit_shift_reg(e, op, d, s, w),
        alu::END => {} // little-endian host: to-le is the identity
        _ => return None,
    }
    Some(())
}

fn emit_alu_imm(e: &mut Emit, op: u8, dst: u8, imm: i64, w: bool) -> Option<()> {
    let d = REGMAP[dst as usize];
    let v32 = imm as i32;
    match op {
        alu::ADD => e.alu_imm(0, d, v32, w),
        alu::SUB => e.alu_imm(5, d, v32, w),
        alu::OR => e.alu_imm(1, d, v32, w),
        alu::AND => e.alu_imm(4, d, v32, w),
        alu::XOR => e.alu_imm(6, d, v32, w),
        alu::MOV => {
            if w {
                e.mov_imm(d, imm)
            } else {
                e.mov_imm32(d, imm as u32)
            }
        }
        alu::MUL => {
            // imul d, d, imm32
            e.rex(w, d, d);
            e.u8(0x69);
            e.modrm(0b11, d, d);
            e.u32(v32 as u32);
        }
        alu::LSH | alu::RSH | alu::ARSH => {
            let ext = match op {
                alu::LSH => 4,
                alu::RSH => 5,
                _ => 7,
            };
            e.rex(w, 0, d);
            e.u8(0xc1);
            e.modrm(0b11, ext, d);
            e.u8(imm as u8 & if w { 63 } else { 31 });
        }
        alu::DIV | alu::MOD => {
            // divisor into r11, then the reg path
            e.mov_imm(R11, imm);
            emit_divmod_r11(e, d, op == alu::MOD, w);
        }
        _ => return None,
    }
    Some(())
}

/// dst = dst /% src, BPF semantics (div by 0 → 0; mod by 0 → dst).
fn emit_divmod(e: &mut Emit, d: u8, s: u8, is_mod: bool, w: bool) {
    if w {
        e.mov_rr(R11, s);
    } else {
        e.mov_rr32(R11, s); // truncate: divisor is the low 32 bits
    }
    emit_divmod_r11(e, d, is_mod, w);
}

fn emit_divmod_r11(e: &mut Emit, d: u8, is_mod: bool, w: bool) {
    // save rax/rdx (they may be live BPF r0/r3)
    e.push(RAX);
    e.push(RDX);
    if w {
        e.mov_rr(RAX, d);
    } else {
        e.mov_rr32(RAX, d); // zero-extend: 32-bit div is 0:eax / r11d
    }
    // xor edx, edx
    e.alu_rr(0x31, RDX, RDX, false);
    // test r11, r11; jz .zero (width matches the division)
    e.alu_rr(0x85, R11, R11, w);
    e.u8(0x74); // jz rel8
    let jz_pos = e.code.len();
    e.u8(0);
    // div r11
    e.rex(w, 0, R11);
    e.u8(0xf7);
    e.modrm(0b11, 6, R11);
    e.u8(0xeb); // jmp rel8 over .zero
    let jmp_pos = e.code.len();
    e.u8(0);
    // .zero: quotient = 0, remainder = dividend
    let zero_off = e.code.len();
    e.code[jz_pos] = (zero_off - (jz_pos + 1)) as u8;
    e.mov_rr(RDX, RAX); // remainder = dividend
    e.alu_rr(0x31, RAX, RAX, false); // quotient = 0
    let done_off = e.code.len();
    e.code[jmp_pos] = (done_off - (jmp_pos + 1)) as u8;
    // result into r11, restore, move to dst
    e.mov_rr(R11, if is_mod { RDX } else { RAX });
    if !w {
        e.mov_rr32(R11, R11); // truncate 32-bit results
    }
    e.pop(RDX);
    e.pop(RAX);
    e.mov_rr(d, R11);
}

/// dst = dst <</>>/>>s src — x86 variable shifts need the count in cl.
fn emit_shift_reg(e: &mut Emit, op: u8, d: u8, s: u8, w: bool) {
    let ext = match op {
        alu::LSH => 4,
        alu::RSH => 5,
        _ => 7, // ARSH
    };
    e.mov_rr(R11, d);
    e.push(RCX);
    e.mov_rr(RCX, s); // if s == rcx this is a no-op move of the same value
    // shl/shr/sar r11, cl
    e.rex(w, 0, R11);
    e.u8(0xd3);
    e.modrm(0b11, ext, R11);
    e.pop(RCX);
    if !w {
        e.mov_rr32(R11, R11);
    }
    e.mov_rr(d, R11);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpf::insn::{self, *};
    use crate::bpf::interp;
    use crate::bpf::maps::{MapDef, MapKind, MapRegistry};
    use crate::bpf::verifier;
    use crate::util::Rng;

    fn env() -> HelperEnv {
        HelperEnv { maps: vec![], printk: None, prog_type: None, stats: None }
    }

    fn jit_run(prog: &[Insn], ctx: *mut u8, env: &HelperEnv) -> u64 {
        let ops = interp::predecode(prog).unwrap();
        let j = JitProgram::compile(&ops).expect("jit");
        unsafe { j.call(ctx, env) }
    }

    #[test]
    fn arithmetic_matches_interp() {
        let progs: Vec<Vec<Insn>> = vec![
            vec![mov64_imm(0, 2), alu64_imm(alu::ADD, 0, 40), exit()],
            vec![mov64_imm(0, 7), alu64_imm(alu::MUL, 0, -6), exit()],
            vec![mov64_imm(0, 85), alu64_imm(alu::DIV, 0, 2), exit()],
            vec![mov64_imm(0, 85), alu64_imm(alu::MOD, 0, 7), exit()],
            vec![mov64_imm(0, -1), alu32_imm(alu::ADD, 0, 1), exit()],
            vec![mov64_imm(0, 1), alu64_imm(alu::LSH, 0, 33), exit()],
            vec![mov64_imm(0, -8), alu64_imm(alu::ARSH, 0, 2), exit()],
            vec![
                mov64_imm(1, 10),
                mov64_imm(0, 100),
                alu64_reg(alu::DIV, 0, 1),
                exit(),
            ],
            vec![
                mov64_imm(1, 0),
                mov64_imm(0, 100),
                alu64_reg(alu::DIV, 0, 1), // div by zero -> 0
                exit(),
            ],
            vec![
                mov64_imm(1, 0),
                mov64_imm(0, 100),
                alu64_reg(alu::MOD, 0, 1), // mod by zero -> dividend
                exit(),
            ],
            vec![
                mov64_imm(4, 3), // r4 = rcx: shift count in the tricky reg
                mov64_imm(0, 1),
                alu64_reg(alu::LSH, 0, 4),
                exit(),
            ],
            vec![
                mov64_imm(3, 21), // r3 = rdx: clobber-prone in div
                mov64_imm(1, 2),
                mov64_imm(0, 84),
                alu64_reg(alu::DIV, 0, 1),
                alu64_reg(alu::ADD, 0, 3),
                exit(),
            ],
        ];
        for (i, p) in progs.iter().enumerate() {
            let ops = interp::predecode(p).unwrap();
            let want = unsafe { interp::execute(&ops, std::ptr::null_mut(), &env()) };
            let got = jit_run(p, std::ptr::null_mut(), &env());
            assert_eq!(got, want, "program {}", i);
        }
    }

    #[test]
    fn branches_and_loops() {
        // sum 0..100
        let prog = [
            mov64_imm(0, 0),
            mov64_imm(2, 0),
            jmp_imm(jmp::JGE, 2, 100, 3),
            alu64_reg(alu::ADD, 0, 2),
            alu64_imm(alu::ADD, 2, 1),
            ja(-4),
            exit(),
        ];
        assert_eq!(jit_run(&prog, std::ptr::null_mut(), &env()), 4950);
        // signed compare
        let prog = [
            mov64_imm(1, -5),
            mov64_imm(0, 0),
            jmp_imm(jmp::JSLT, 1, 0, 1),
            exit(),
            mov64_imm(0, 1),
            exit(),
        ];
        assert_eq!(jit_run(&prog, std::ptr::null_mut(), &env()), 1);
    }

    #[test]
    fn ctx_and_stack_access() {
        let mut ctx = [0u8; 16];
        ctx[0..8].copy_from_slice(&123u64.to_le_bytes());
        let prog = [
            ldx(size::DW, 0, 1, 0),
            alu64_imm(alu::ADD, 0, 1),
            stx(size::W, 1, 0, 8),
            st_imm(size::B, 10, -1, 7),
            ldx(size::B, 2, 10, -1),
            alu64_reg(alu::ADD, 0, 2),
            exit(),
        ];
        let r = jit_run(&prog, ctx.as_mut_ptr(), &env());
        assert_eq!(r, 131); // 124 + 7
        assert_eq!(u32::from_le_bytes(ctx[8..12].try_into().unwrap()), 124);
    }

    #[test]
    fn atomics_match_interp() {
        // each case: run interp and JIT on identical 8-aligned
        // buffers, compare r0 AND final memory
        let progs: Vec<Vec<Insn>> = vec![
            // lock add64 (fetchless)
            vec![mov64_imm(2, 5), atomic_insn(size::DW, 1, 2, 0, atomic::ADD), mov64_imm(0, 0), exit()],
            // lock fetchadd64: r0 = old value
            vec![
                mov64_imm(2, 5),
                atomic_insn(size::DW, 1, 2, 0, atomic::ADD | atomic::FETCH),
                mov64_reg(0, 2),
                exit(),
            ],
            // fetchadd into r0 itself (s == rax path)
            vec![
                mov64_imm(0, 3),
                atomic_insn(size::DW, 1, 0, 0, atomic::ADD | atomic::FETCH),
                exit(),
            ],
            // 32-bit fetchadd zero-extends
            vec![
                mov64_imm(2, -1),
                atomic_insn(size::W, 1, 2, 0, atomic::ADD | atomic::FETCH),
                mov64_reg(0, 2),
                exit(),
            ],
            // xchg64
            vec![
                mov64_imm(2, 99),
                atomic_insn(size::DW, 1, 2, 8, atomic::XCHG),
                mov64_reg(0, 2),
                exit(),
            ],
            // cmpxchg64 success (mem[0]=10, compare 10)
            vec![
                mov64_imm(0, 10),
                mov64_imm(2, 77),
                atomic_insn(size::DW, 1, 2, 0, atomic::CMPXCHG),
                exit(),
            ],
            // cmpxchg64 failure (compare 11 != 10): r0 = observed 10
            vec![
                mov64_imm(0, 11),
                mov64_imm(2, 77),
                atomic_insn(size::DW, 1, 2, 0, atomic::CMPXCHG),
                exit(),
            ],
            // cmpxchg32: success path must still zero-extend r0
            {
                let hi = lddw(0, 0, 0xdead_beef_0000_000a);
                vec![
                    hi[0],
                    hi[1],
                    mov64_imm(2, 4),
                    atomic_insn(size::W, 1, 2, 0, atomic::CMPXCHG),
                    exit(),
                ]
            },
            // cmpxchg loop forms: and/or/xor, fetch and fetchless
            vec![mov64_imm(2, 6), atomic_insn(size::DW, 1, 2, 0, atomic::AND), mov64_imm(0, 0), exit()],
            vec![
                mov64_imm(2, 0x101),
                atomic_insn(size::DW, 1, 2, 0, atomic::OR | atomic::FETCH),
                mov64_reg(0, 2),
                exit(),
            ],
            vec![
                mov64_imm(2, 0xff),
                atomic_insn(size::W, 1, 2, 8, atomic::XOR | atomic::FETCH),
                mov64_reg(0, 2),
                exit(),
            ],
            // fetch-and into r0 itself through the loop lowering
            vec![
                mov64_imm(0, 0xf0),
                atomic_insn(size::DW, 1, 0, 0, atomic::AND | atomic::FETCH),
                exit(),
            ],
            // dst in r0 (rax as base pointer) for the loop lowering
            vec![
                mov64_reg(0, 1),
                mov64_imm(2, 0x0f),
                atomic_insn(size::DW, 0, 2, 0, atomic::XOR),
                mov64_imm(0, 0),
                exit(),
            ],
        ];
        for (i, p) in progs.iter().enumerate() {
            let mut mem_i = [10u64, 0u64];
            let mut mem_j = [10u64, 0u64];
            let ops = interp::predecode(p).unwrap();
            let want = unsafe { interp::execute(&ops, mem_i.as_mut_ptr() as *mut u8, &env()) };
            let got = jit_run(p, mem_j.as_mut_ptr() as *mut u8, &env());
            assert_eq!(got, want, "program {}: r0 mismatch", i);
            assert_eq!(mem_j, mem_i, "program {}: final memory mismatch", i);
        }
    }

    #[test]
    fn helper_call_map_roundtrip() {
        let reg = MapRegistry::new();
        let m = reg
            .create_or_get(&MapDef {
                name: "m".into(),
                kind: MapKind::Array,
                key_size: 4,
                value_size: 8,
                max_entries: 4,
            })
            .unwrap();
        m.write_u64(0, 777).unwrap();
        let henv = HelperEnv::new(&reg, &[m.id]).unwrap();
        let mut p = vec![];
        p.extend(ld_map_fd(1, m.id));
        p.push(st_imm(size::W, 10, -4, 0));
        p.push(mov64_reg(2, 10));
        p.push(alu64_imm(alu::ADD, 2, -4));
        p.push(insn::call(1));
        p.push(jmp_imm(jmp::JNE, 0, 0, 2));
        p.push(mov64_imm(0, 0));
        p.push(exit());
        p.push(ldx(size::DW, 0, 0, 0));
        p.push(exit());
        assert_eq!(jit_run(&p, std::ptr::null_mut(), &henv), 777);
    }

    #[test]
    fn ringbuf_reserve_submit_via_jit() {
        let reg = MapRegistry::new();
        let m = reg
            .create_or_get(&MapDef {
                name: "rb".into(),
                kind: MapKind::RingBuf,
                key_size: 0,
                value_size: 0,
                max_entries: 4096,
            })
            .unwrap();
        let henv = HelperEnv::new(&reg, &[m.id]).unwrap();
        // reserve 16, null-check, write two u64s, submit, return 1
        let mut p = vec![];
        p.extend(ld_map_fd(1, m.id));
        p.push(mov64_imm(2, 16));
        p.push(mov64_imm(3, 0));
        p.push(insn::call(131));
        p.push(jmp_imm(jmp::JNE, 0, 0, 2));
        p.push(mov64_imm(0, 0));
        p.push(exit());
        p.push(mov64_reg(6, 0));
        p.push(st_imm(size::DW, 6, 0, 111));
        p.push(st_imm(size::DW, 6, 8, 222));
        p.push(mov64_reg(1, 6));
        p.push(mov64_imm(2, 0));
        p.push(insn::call(132));
        p.push(mov64_imm(0, 1));
        p.push(exit());
        assert_eq!(jit_run(&p, std::ptr::null_mut(), &henv), 1);
        let mut got = vec![];
        m.ringbuf_drain(&mut |b| {
            got.push(u64::from_le_bytes(b[..8].try_into().unwrap()));
            got.push(u64::from_le_bytes(b[8..16].try_into().unwrap()));
        });
        assert_eq!(got, vec![111, 222]);
    }

    #[test]
    fn subprog_call_matches_interp() {
        // main keeps r6/r7 live across the call; sub: r0 = r1 * 2 + r2
        let prog = [
            mov64_imm(6, 100),
            mov64_imm(7, 10),
            mov64_imm(1, 4),
            mov64_imm(2, 5),
            insn::call_pseudo(3), // -> 8
            alu64_reg(alu::ADD, 0, 6),
            alu64_reg(alu::ADD, 0, 7),
            exit(),
            mov64_reg(0, 1), // sub
            alu64_imm(alu::MUL, 0, 2),
            alu64_reg(alu::ADD, 0, 2),
            exit(),
        ];
        let ops = interp::predecode(&prog).unwrap();
        let want = unsafe { interp::execute(&ops, std::ptr::null_mut(), &env()) };
        assert_eq!(want, 123);
        assert_eq!(jit_run(&prog, std::ptr::null_mut(), &env()), want);
    }

    #[test]
    fn subprog_own_stack_and_helper_alignment() {
        let reg = MapRegistry::new();
        let henv = HelperEnv::new(&reg, &[]).unwrap();
        let prog = [
            mov64_imm(6, 7),              // 0
            insn::call_pseudo(2),         // 1 -> 4
            alu64_reg(alu::ADD, 0, 6),    // 2: r6 preserved by the callee
            exit(),                       // 3
            st_imm(size::DW, 10, -8, 40), // 4: sub writes its own frame
            insn::call(5),                // 5: helper inside a subprog
            ldx(size::DW, 0, 10, -8),     // 6: frame survived the helper
            alu64_imm(alu::ADD, 0, 2),    // 7
            exit(),                       // 8
        ];
        assert_eq!(jit_run(&prog, std::ptr::null_mut(), &henv), 49);
    }

    #[test]
    fn nested_subprog_calls_match_interp() {
        // main -> a -> b, each preserving the caller's r6
        let prog = [
            mov64_imm(6, 1),           // 0
            mov64_imm(1, 10),          // 1
            insn::call_pseudo(2),      // 2 -> 5 (a)
            alu64_reg(alu::ADD, 0, 6), // 3
            exit(),                    // 4
            mov64_reg(6, 1),           // 5: a's own r6
            insn::call_pseudo(2),      // 6 -> 9 (b)
            alu64_reg(alu::ADD, 0, 6), // 7: a's r6 survived b
            exit(),                    // 8
            mov64_imm(0, 100),         // 9: b
            exit(),                    // 10
        ];
        // b returns 100; a adds its r6 (=10) -> 110; main adds 1 -> 111
        let ops = interp::predecode(&prog).unwrap();
        let want = unsafe { interp::execute(&ops, std::ptr::null_mut(), &env()) };
        assert_eq!(want, 111);
        assert_eq!(jit_run(&prog, std::ptr::null_mut(), &env()), want);
    }

    #[test]
    fn callee_saved_regs_survive_helper_calls() {
        let reg = MapRegistry::new();
        let henv = HelperEnv::new(&reg, &[]).unwrap();
        let prog = [
            mov64_imm(6, 600),
            mov64_imm(7, 70),
            mov64_imm(8, 8),
            insn::call(5), // ktime
            mov64_reg(0, 6),
            alu64_reg(alu::ADD, 0, 7),
            alu64_reg(alu::ADD, 0, 8),
            exit(),
        ];
        assert_eq!(jit_run(&prog, std::ptr::null_mut(), &henv), 678);
    }

    /// Differential fuzz: random (verifier-shaped) ALU/branch programs
    /// must agree between JIT and interpreter.
    #[test]
    fn differential_fuzz_alu_vs_interp() {
        let mut rng = Rng::new(0xd1ff);
        for case in 0..400 {
            let mut p = vec![];
            // init r0..r5 with random constants
            for r in 0..6u8 {
                p.push(mov64_imm(r, rng.next_u32() as i32));
            }
            for _ in 0..12 {
                let dst = (rng.below(6)) as u8;
                let src = (rng.below(6)) as u8;
                let ops64 = [
                    alu::ADD,
                    alu::SUB,
                    alu::MUL,
                    alu::DIV,
                    alu::MOD,
                    alu::OR,
                    alu::AND,
                    alu::XOR,
                    alu::MOV,
                    alu::LSH,
                    alu::RSH,
                    alu::ARSH,
                ];
                let op = ops64[rng.below(ops64.len() as u64) as usize];
                match rng.below(4) {
                    0 => p.push(alu64_reg(op, dst, src)),
                    1 => p.push(alu32_reg(op, dst, src)),
                    2 => p.push(alu64_imm(op, dst, rng.next_u32() as i32)),
                    _ => {
                        let imm = rng.next_u32() as i32;
                        // shifts by huge immediates differ across
                        // hardware; keep them in range like the
                        // verifier's codegen does
                        let imm = if matches!(op, alu::LSH | alu::RSH | alu::ARSH) {
                            imm.rem_euclid(64)
                        } else {
                            imm
                        };
                        p.push(alu32_imm(op, dst, imm.rem_euclid(32).max(1)));
                        let _ = imm;
                    }
                }
            }
            p.push(exit());
            let ops = interp::predecode(&p).unwrap();
            let want = unsafe { interp::execute(&ops, std::ptr::null_mut(), &env()) };
            let j = JitProgram::compile(&ops).expect("jit");
            let got = unsafe { j.call(std::ptr::null_mut(), &env()) };
            assert_eq!(got, want, "case {} program:\n{}", case, insn::disasm(&p));
        }
    }

    /// verify → facts → predecode → remap: the exact fact pipeline
    /// the load path runs, for driving `compile_with_unchecked`.
    fn ops_and_facts(
        prog: &[Insn],
        pt: crate::bpf::helpers::ProgType,
        ctx: &verifier::CtxLayout,
        maps: &std::collections::HashMap<u32, MapDef>,
    ) -> (Vec<Op>, Vec<InsnFacts>) {
        let info = verifier::verify(prog, pt, ctx, maps).expect("verifies");
        let (ops, slot2op) = interp::predecode_mapped(prog).unwrap();
        let facts = interp::remap_facts(&info.facts, &slot2op, ops.len());
        (ops, facts)
    }

    fn tuner_ctx() -> verifier::CtxLayout {
        verifier::CtxLayout { size: 64, read: vec![(0, 64)], write: vec![(32, 32)] }
    }

    fn array_fixture(value_at_2: u64) -> (MapRegistry, u32, std::collections::HashMap<u32, MapDef>)
    {
        let reg = MapRegistry::new();
        let m = reg
            .create_or_get(&MapDef {
                name: "m".into(),
                kind: MapKind::Array,
                key_size: 4,
                value_size: 8,
                max_entries: 4,
            })
            .unwrap();
        m.write_u64(2, value_at_2).unwrap();
        let id = m.id;
        let mut defs = std::collections::HashMap::new();
        defs.insert(id, m.def.clone());
        (reg, id, defs)
    }

    /// Trailer shared by the lookup tests: null-check r0, return the
    /// looked-up u64 (or 0 on null).
    fn lookup_tail(p: &mut Vec<Insn>) {
        p.push(jmp_imm(jmp::JNE, 0, 0, 2));
        p.push(mov64_imm(0, 0));
        p.push(exit());
        p.push(ldx(size::DW, 0, 0, 0));
        p.push(exit());
    }

    #[test]
    fn inline_const_key_lookup_matches_trampoline_and_interp() {
        let (reg, id, defs) = array_fixture(777);
        let henv = HelperEnv::new(&reg, &[id]).unwrap();
        let mut p = vec![];
        p.extend(ld_map_fd(1, id));
        p.push(st_imm(size::DW, 10, -8, 2)); // tracked spill → const key 2
        p.push(mov64_reg(2, 10));
        p.push(alu64_imm(alu::ADD, 2, -8));
        p.push(insn::call(1));
        lookup_tail(&mut p);
        let (ops, facts) =
            ops_and_facts(&p, crate::bpf::helpers::ProgType::Tuner, &tuner_ctx(), &defs);
        let opts = JitOptions { facts: Some(&facts), env: Some(&henv), inline: None };
        let jin = JitProgram::compile_with_unchecked(&ops, &opts).expect("jit");
        let joff =
            JitProgram::compile_with_unchecked(&ops, &JitOptions { inline: Some(false), ..opts })
                .expect("jit");
        let want = unsafe { interp::execute(&ops, std::ptr::null_mut(), &henv) };
        assert_eq!(want, 777);
        assert_eq!(unsafe { jin.call(std::ptr::null_mut(), &henv) }, want);
        assert_eq!(unsafe { joff.call(std::ptr::null_mut(), &henv) }, want);
        let s = jin.inline_stats();
        assert_eq!(s.inlined_lookups, 1, "const-key lookup must address-inline");
        assert_eq!(s.bounds_elided, 1, "constant in-range index discharges the check");
        assert_eq!(s.trampoline_calls, 0);
        assert_eq!(
            joff.inline_stats(),
            JitInlineStats { trampoline_calls: 1, ..JitInlineStats::default() },
            "inline=Some(false) must keep every site on the trampoline"
        );
    }

    #[test]
    fn nonconstant_key_falls_back_to_generic_call() {
        // a 4-byte store is untracked by the spill model, so the
        // verifier emits no key fact — the site must NOT address-inline
        // (it falls back to the generic direct-call/trampoline tier)
        let (reg, id, defs) = array_fixture(555);
        let henv = HelperEnv::new(&reg, &[id]).unwrap();
        let mut p = vec![];
        p.extend(ld_map_fd(1, id));
        p.push(st_imm(size::W, 10, -8, 2)); // untracked: no key fact
        p.push(mov64_reg(2, 10));
        p.push(alu64_imm(alu::ADD, 2, -8));
        p.push(insn::call(1));
        lookup_tail(&mut p);
        let (ops, facts) =
            ops_and_facts(&p, crate::bpf::helpers::ProgType::Tuner, &tuner_ctx(), &defs);
        let opts = JitOptions { facts: Some(&facts), env: Some(&henv), inline: None };
        let jin = JitProgram::compile_with_unchecked(&ops, &opts).expect("jit");
        let want = unsafe { interp::execute(&ops, std::ptr::null_mut(), &henv) };
        assert_eq!(want, 555);
        assert_eq!(unsafe { jin.call(std::ptr::null_mut(), &henv) }, want);
        let s = jin.inline_stats();
        assert_eq!(s.inlined_lookups, 0, "no key fact → no address inlining");
        assert_eq!(s.bounds_elided, 0);
        assert_eq!(s.direct_calls, 1, "known map still skips dispatch via direct call");
    }

    #[test]
    fn undischarged_bound_keeps_index_check() {
        // key bounded to [0,9] but max_entries is 4: the bound is NOT
        // discharged, so the inlined code must keep the cmp — an
        // out-of-capacity runtime index still observes a null lookup
        let (reg, id, defs) = array_fixture(999);
        let henv = HelperEnv::new(&reg, &[id]).unwrap();
        let mut p = vec![];
        p.extend(ld_map_fd(6, id)); // 0-1
        p.push(ldx(size::W, 3, 1, 0)); // 2: r3 = ctx[0]
        p.push(jmp_imm(jmp::JGT, 3, 9, 10)); // 3: -> 14 (out)
        p.push(stx(size::DW, 10, 3, -8)); // 4: tracked spill, umax 9
        p.push(mov64_reg(1, 6)); // 5
        p.push(mov64_reg(2, 10)); // 6
        p.push(alu64_imm(alu::ADD, 2, -8)); // 7
        p.push(insn::call(1)); // 8
        p.push(jmp_imm(jmp::JNE, 0, 0, 2)); // 9: -> 12
        p.push(mov64_imm(0, 0)); // 10
        p.push(exit()); // 11
        p.push(ldx(size::DW, 0, 0, 0)); // 12
        p.push(exit()); // 13
        p.push(mov64_imm(0, 42)); // 14: out
        p.push(exit()); // 15
        let (ops, facts) =
            ops_and_facts(&p, crate::bpf::helpers::ProgType::Tuner, &tuner_ctx(), &defs);
        let opts = JitOptions { facts: Some(&facts), env: Some(&henv), inline: None };
        let jin = JitProgram::compile_with_unchecked(&ops, &opts).expect("jit");
        let joff =
            JitProgram::compile_with_unchecked(&ops, &JitOptions { inline: Some(false), ..opts })
                .expect("jit");
        let s = jin.inline_stats();
        assert_eq!(s.inlined_lookups, 1, "bounded key still address-inlines");
        assert_eq!(s.bounds_elided, 0, "undischarged bound must keep the check");
        // in-capacity index → the stored value; out-of-capacity (but
        // in-bound) index → null path; both engines and modes agree
        for idx in [2u32, 5u32] {
            let mut ctx = [0u8; 64];
            ctx[0..4].copy_from_slice(&idx.to_le_bytes());
            let want = unsafe { interp::execute(&ops, ctx.as_mut_ptr(), &henv) };
            assert_eq!(want, if idx == 2 { 999 } else { 0 });
            assert_eq!(unsafe { jin.call(ctx.as_mut_ptr(), &henv) }, want, "idx {}", idx);
            assert_eq!(unsafe { joff.call(ctx.as_mut_ptr(), &henv) }, want, "idx {}", idx);
        }
    }

    #[test]
    fn inline_ringbuf_submit_matches_trampoline_bytes() {
        let reg = MapRegistry::new();
        let m = reg
            .create_or_get(&MapDef {
                name: "rb".into(),
                kind: MapKind::RingBuf,
                key_size: 0,
                value_size: 0,
                max_entries: 4096,
            })
            .unwrap();
        let henv = HelperEnv::new(&reg, &[m.id]).unwrap();
        let mut defs = std::collections::HashMap::new();
        defs.insert(m.id, m.def.clone());
        let mut p = vec![];
        p.extend(ld_map_fd(1, m.id));
        p.push(mov64_imm(2, 16));
        p.push(mov64_imm(3, 0));
        p.push(insn::call(131));
        p.push(jmp_imm(jmp::JNE, 0, 0, 2));
        p.push(mov64_imm(0, 0));
        p.push(exit());
        p.push(mov64_reg(6, 0));
        p.push(st_imm(size::DW, 6, 0, 111));
        p.push(st_imm(size::DW, 6, 8, 222));
        p.push(mov64_reg(1, 6));
        p.push(mov64_imm(2, 0));
        p.push(insn::call(132));
        p.push(mov64_imm(0, 1));
        p.push(exit());
        let prof = verifier::CtxLayout { size: 32, read: vec![(0, 32)], write: vec![] };
        let (ops, facts) =
            ops_and_facts(&p, crate::bpf::helpers::ProgType::Profiler, &prof, &defs);
        let opts = JitOptions { facts: Some(&facts), env: Some(&henv), inline: None };
        let jin = JitProgram::compile_with_unchecked(&ops, &opts).expect("jit");
        let joff =
            JitProgram::compile_with_unchecked(&ops, &JitOptions { inline: Some(false), ..opts })
                .expect("jit");
        let s = jin.inline_stats();
        assert_eq!(s.inlined_ringbuf, 1, "submit must inline to header stores");
        assert_eq!(s.direct_calls, 1, "reserve goes through the direct entry point");
        assert_eq!(joff.inline_stats().trampoline_calls, 2);
        let drain = |label: &str| {
            let mut got = vec![];
            m.ringbuf_drain(&mut |b| {
                got.push(u64::from_le_bytes(b[..8].try_into().unwrap()));
                got.push(u64::from_le_bytes(b[8..16].try_into().unwrap()));
            });
            assert_eq!(got, vec![111, 222], "{}", label);
        };
        assert_eq!(unsafe { jin.call(std::ptr::null_mut(), &henv) }, 1);
        drain("inlined");
        assert_eq!(unsafe { joff.call(std::ptr::null_mut(), &henv) }, 1);
        drain("trampoline");
        assert_eq!(unsafe { interp::execute(&ops, std::ptr::null_mut(), &henv) }, 1);
        drain("interp");
    }

    #[test]
    fn compile_unchecked_bypasses_env_gate() {
        // The NCCLBPF_NO_JIT env path itself is covered end-to-end in
        // rust/tests/integration_cli.rs (child process, private env) —
        // mutating the global environment here would race with other
        // tests that call JitProgram::compile concurrently.
        let ops = interp::predecode(&[mov64_imm(0, 1), exit()]).unwrap();
        let compiled = JitProgram::compile_unchecked(&ops);
        if cfg!(all(unix, target_arch = "x86_64")) {
            assert!(compiled.is_some());
        } else {
            assert!(compiled.is_none(), "JIT must decline on unsupported targets");
        }
    }
}
