//! Text assembler for eBPF programs.
//!
//! Used by tests, the CLI (`ncclbpf asm`), and as a debugging aid; the
//! restricted-C compiler ([`crate::bpfc`]) emits instructions directly.
//!
//! Syntax (one statement per line, `;` or `#` comments):
//!
//! ```text
//! map latency_map array key=4 value=16 entries=64
//!
//! prog tuner size_aware
//!   mov64 r2, 4
//!   ldmap r1, latency_map        ; pseudo map load (emits lddw + reloc)
//!   ldxw  r3, [r1+8]
//!   stxdw [r10-8], r3
//!   jne   r0, 0, not_null
//!   mov64 r0, 0
//!   exit
//! not_null:
//!   mov64 r0, 1
//!   exit
//! ```

use super::insn::{self, alu, class, jmp, size, src, Insn};
use super::maps::{MapDef, MapKind};
use super::object::{ObjProgram, Object, Reloc};
use std::collections::HashMap;

/// Assembly failure with its 1-based source line.
#[derive(Debug)]
pub struct AsmError {
    /// 1-based source line of the offending statement
    pub line: usize,
    /// what went wrong
    pub message: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "asm error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for AsmError {}

type AResult<T> = Result<T, AsmError>;

fn aerr<T>(line: usize, msg: impl Into<String>) -> AResult<T> {
    Err(AsmError { line, message: msg.into() })
}

/// A partially assembled instruction: branch targets may be labels.
enum Pending {
    Done(Insn),
    /// conditional/unconditional branch to a label
    Branch { opcode: u8, dst: u8, src_reg: u8, imm: i32, label: String },
    /// bpf-to-bpf call to a labelled subprogram (imm = relative offset)
    PseudoCall { label: String },
    /// lddw map reference (expands to 2 slots + reloc)
    MapRef { dst: u8, map: String },
    /// lddw 64-bit immediate (expands to 2 slots)
    Imm64 { dst: u8, v: u64 },
}

fn parse_reg(tok: &str, line: usize) -> AResult<u8> {
    let t = tok.trim_end_matches(',');
    if let Some(n) = t.strip_prefix('r').or_else(|| t.strip_prefix('w')) {
        if let Ok(v) = n.parse::<u8>() {
            if v <= 10 {
                return Ok(v);
            }
        }
    }
    aerr(line, format!("expected register, got '{}'", tok))
}

fn parse_imm(tok: &str, line: usize) -> AResult<i64> {
    let t = tok.trim_end_matches(',');
    let (neg, t) = if let Some(s) = t.strip_prefix('-') { (true, s) } else { (false, t) };
    let v = if let Some(hex) = t.strip_prefix("0x") {
        i64::from_str_radix(hex, 16)
    } else {
        t.parse::<i64>()
    };
    match v {
        Ok(v) => Ok(if neg { -v } else { v }),
        Err(_) => aerr(line, format!("expected immediate, got '{}'", tok)),
    }
}

/// parse `[rN+off]` / `[rN-off]` / `[rN]`
fn parse_mem(tok: &str, line: usize) -> AResult<(u8, i16)> {
    let t = tok.trim_end_matches(',');
    let inner = t
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| AsmError { line, message: format!("expected [reg+off], got '{}'", tok) })?;
    let (regpart, off) = if let Some(i) = inner.find(['+', '-']) {
        let sign = if inner.as_bytes()[i] == b'-' { -1i32 } else { 1 };
        let off: i32 = inner[i + 1..]
            .trim()
            .parse()
            .map_err(|_| AsmError { line, message: format!("bad offset in '{}'", tok) })?;
        (&inner[..i], sign * off)
    } else {
        (inner, 0)
    };
    let reg = parse_reg(regpart.trim(), line)?;
    if off > i16::MAX as i32 || off < i16::MIN as i32 {
        return aerr(line, "offset out of i16 range");
    }
    Ok((reg, off as i16))
}

fn alu_op(name: &str) -> Option<u8> {
    Some(match name {
        "add" => alu::ADD,
        "sub" => alu::SUB,
        "mul" => alu::MUL,
        "div" => alu::DIV,
        "or" => alu::OR,
        "and" => alu::AND,
        "lsh" => alu::LSH,
        "rsh" => alu::RSH,
        "mod" => alu::MOD,
        "xor" => alu::XOR,
        "mov" => alu::MOV,
        "arsh" => alu::ARSH,
        _ => return None,
    })
}

fn jmp_op(name: &str) -> Option<u8> {
    Some(match name {
        "jeq" => jmp::JEQ,
        "jgt" => jmp::JGT,
        "jge" => jmp::JGE,
        "jset" => jmp::JSET,
        "jne" => jmp::JNE,
        "jsgt" => jmp::JSGT,
        "jsge" => jmp::JSGE,
        "jlt" => jmp::JLT,
        "jle" => jmp::JLE,
        "jslt" => jmp::JSLT,
        "jsle" => jmp::JSLE,
        _ => return None,
    })
}

fn size_suffix(name: &str) -> Option<u8> {
    Some(match name {
        "b" => size::B,
        "h" => size::H,
        "w" => size::W,
        "dw" => size::DW,
        _ => return None,
    })
}

/// Assemble a full source file into an [`Object`].
pub fn assemble(source: &str) -> AResult<Object> {
    let mut maps: Vec<MapDef> = Vec::new();
    let mut progs: Vec<ObjProgram> = Vec::new();

    // current program state
    let mut cur: Option<(String, String, Vec<Pending>, HashMap<String, usize>)> = None;

    // finalize: resolve labels, expand pseudo ops
    fn finish(
        line: usize,
        sec: String,
        name: String,
        pendings: Vec<Pending>,
        labels: HashMap<String, usize>,
    ) -> AResult<ObjProgram> {
        // compute slot index of each pending (lddw variants take 2 slots)
        let mut slot_of = Vec::with_capacity(pendings.len() + 1);
        let mut slots = 0u32;
        for p in &pendings {
            slot_of.push(slots);
            slots += match p {
                Pending::MapRef { .. } | Pending::Imm64 { .. } => 2,
                _ => 1,
            };
        }
        slot_of.push(slots);

        let mut insns = Vec::with_capacity(slots as usize);
        let mut relocs = Vec::new();
        for (i, p) in pendings.into_iter().enumerate() {
            match p {
                Pending::Done(ins) => insns.push(ins),
                Pending::Imm64 { dst, v } => insns.extend(insn::lddw(dst, 0, v)),
                Pending::MapRef { dst, map } => {
                    relocs.push(Reloc { insn_idx: slot_of[i], map_name: map });
                    insns.extend(insn::ld_map_fd(dst, 0));
                }
                Pending::Branch { opcode, dst, src_reg, imm, label } => {
                    let tgt = *labels.get(&label).ok_or_else(|| AsmError {
                        line,
                        message: format!("undefined label '{}'", label),
                    })?;
                    let off = slot_of[tgt] as i64 - (slot_of[i] as i64 + 1);
                    if off > i16::MAX as i64 || off < i16::MIN as i64 {
                        return aerr(line, format!("branch to '{}' out of range", label));
                    }
                    insns.push(Insn::new(opcode, dst, src_reg, off as i16, imm));
                }
                Pending::PseudoCall { label } => {
                    let tgt = *labels.get(&label).ok_or_else(|| AsmError {
                        line,
                        message: format!(
                            "'{}' is neither a helper name nor a defined label",
                            label
                        ),
                    })?;
                    let imm = slot_of[tgt] as i64 - (slot_of[i] as i64 + 1);
                    insns.push(insn::call_pseudo(imm as i32));
                }
            }
        }
        Ok(ObjProgram { section: sec, name, insns, relocs })
    }

    for (lineno, raw) in source.lines().enumerate() {
        let line = lineno + 1;
        let text = raw.split(';').next().unwrap().split('#').next().unwrap().trim();
        if text.is_empty() {
            continue;
        }
        let toks: Vec<&str> = text.split_whitespace().collect();

        // label?
        if toks.len() == 1 && toks[0].ends_with(':') {
            let lbl = toks[0].trim_end_matches(':').to_string();
            if let Some((_, _, pend, labels)) = cur.as_mut() {
                if labels.insert(lbl.clone(), pend.len()).is_some() {
                    return aerr(line, format!("duplicate label '{}'", lbl));
                }
            } else {
                return aerr(line, "label outside program");
            }
            continue;
        }

        match toks[0] {
            "map" => {
                // map NAME KIND [key=N] value=N entries=N
                // (ringbuf: map NAME ringbuf entries=BYTES — no key/value)
                if toks.len() < 4 || toks.len() > 6 {
                    return aerr(
                        line,
                        "usage: map NAME array|hash|percpu|ringbuf|progarray \
                         [key=N] [value=N] entries=N",
                    );
                }
                let kind = match toks[2] {
                    "array" => MapKind::Array,
                    "hash" => MapKind::Hash,
                    "percpu" => MapKind::PerCpuArray,
                    "ringbuf" => MapKind::RingBuf,
                    "progarray" => MapKind::ProgArray,
                    k => return aerr(line, format!("unknown map kind '{}'", k)),
                };
                let mut key_size = 0;
                let mut value_size = 0;
                let mut max_entries = 0;
                for t in &toks[3..] {
                    if let Some(v) = t.strip_prefix("key=") {
                        key_size = v.parse().map_err(|_| AsmError {
                            line,
                            message: "bad key=".into(),
                        })?;
                    } else if let Some(v) = t.strip_prefix("value=") {
                        value_size = v.parse().map_err(|_| AsmError {
                            line,
                            message: "bad value=".into(),
                        })?;
                    } else if let Some(v) = t.strip_prefix("entries=") {
                        max_entries = v.parse().map_err(|_| AsmError {
                            line,
                            message: "bad entries=".into(),
                        })?;
                    }
                }
                // allow key= omitted for array maps; ringbufs have none;
                // prog arrays use the fixed kernel ABI (4-byte key/value)
                if key_size == 0 && !matches!(kind, MapKind::Hash | MapKind::RingBuf) {
                    key_size = 4;
                }
                if kind == MapKind::ProgArray && value_size == 0 {
                    value_size = 4;
                }
                let def = MapDef { name: toks[1].into(), kind, key_size, value_size, max_entries };
                def.validate().map_err(|m| AsmError { line, message: m })?;
                maps.push(def);
            }
            "prog" => {
                if toks.len() != 3 {
                    return aerr(line, "usage: prog SECTION NAME");
                }
                if let Some((sec, name, pend, labels)) = cur.take() {
                    progs.push(finish(line, sec, name, pend, labels)?);
                }
                cur = Some((toks[1].into(), toks[2].into(), Vec::new(), HashMap::new()));
            }
            mnemonic => {
                let Some((_, _, pend, _)) = cur.as_mut() else {
                    return aerr(line, "instruction outside of a prog section");
                };
                let p = parse_insn(mnemonic, &toks, line)?;
                pend.push(p);
            }
        }
    }
    if let Some((sec, name, pend, labels)) = cur.take() {
        progs.push(finish(source.lines().count(), sec, name, pend, labels)?);
    }
    Ok(Object { maps, progs })
}

fn parse_insn(mnemonic: &str, toks: &[&str], line: usize) -> AResult<Pending> {
    // alu: <op>64 / <op>32  dst, (src|imm)
    for (suffix, cls) in [("64", class::ALU64), ("32", class::ALU)] {
        if let Some(base) = mnemonic.strip_suffix(suffix) {
            if base == "neg" {
                let dst = parse_reg(toks[1], line)?;
                return Ok(Pending::Done(Insn::new(cls | alu::NEG, dst, 0, 0, 0)));
            }
            if let Some(op) = alu_op(base) {
                if toks.len() != 3 {
                    return aerr(line, format!("usage: {} rD, rS|imm", mnemonic));
                }
                let dst = parse_reg(toks[1], line)?;
                return Ok(Pending::Done(if toks[2].starts_with('r') || toks[2].starts_with('w') {
                    let s = parse_reg(toks[2], line)?;
                    Insn::new(cls | src::X | op, dst, s, 0, 0)
                } else {
                    let imm = parse_imm(toks[2], line)?;
                    Insn::new(cls | src::K | op, dst, 0, 0, imm as i32)
                }));
            }
        }
    }
    // loads: ldx{b,h,w,dw} rD, [rS+off]
    if let Some(sfx) = mnemonic.strip_prefix("ldx").and_then(size_suffix) {
        if toks.len() != 3 {
            return aerr(line, "usage: ldxW rD, [rS+off]");
        }
        let dst = parse_reg(toks[1], line)?;
        let (s, off) = parse_mem(toks[2], line)?;
        return Ok(Pending::Done(insn::ldx(sfx, dst, s, off)));
    }
    // stores: stx{b,h,w,dw} [rD+off], rS   |   st{b,h,w,dw} [rD+off], imm
    if let Some(sfx) = mnemonic.strip_prefix("stx").and_then(size_suffix) {
        if toks.len() != 3 {
            return aerr(line, "usage: stxW [rD+off], rS");
        }
        let (d, off) = parse_mem(toks[1], line)?;
        let s = parse_reg(toks[2], line)?;
        return Ok(Pending::Done(insn::stx(sfx, d, s, off)));
    }
    if mnemonic != "st" {
        if let Some(sfx) = mnemonic.strip_prefix("st").and_then(size_suffix) {
            if toks.len() != 3 {
                return aerr(line, "usage: stW [rD+off], imm");
            }
            let (d, off) = parse_mem(toks[1], line)?;
            let imm = parse_imm(toks[2], line)?;
            return Ok(Pending::Done(insn::st_imm(sfx, d, off, imm as i32)));
        }
    }
    // atomics: lock OP{32,64} [rD+off], rS       (fetchless rmw)
    //          lock fetchOP{32,64} rS, [rD+off]  (old value lands in rS)
    if mnemonic == "lock" {
        if toks.len() != 4 {
            return aerr(
                line,
                "usage: lock OP64 [rD+off], rS  |  lock fetchOP64 rS, [rD+off]",
            );
        }
        let sub = toks[1];
        for (suffix, sz) in [("64", size::DW), ("32", size::W)] {
            if let Some(base) = sub.strip_suffix(suffix) {
                let (name, fetch) = match base.strip_prefix("fetch") {
                    Some(n) => (n, true),
                    None => (base, false),
                };
                let aop = match name {
                    "add" => insn::atomic::ADD,
                    "or" => insn::atomic::OR,
                    "and" => insn::atomic::AND,
                    "xor" => insn::atomic::XOR,
                    _ => return aerr(line, format!("unknown atomic op '{}'", sub)),
                };
                let aop = if fetch { aop | insn::atomic::FETCH } else { aop };
                return Ok(Pending::Done(if fetch {
                    let s = parse_reg(toks[2], line)?;
                    let (d, off) = parse_mem(toks[3], line)?;
                    insn::atomic_insn(sz, d, s, off, aop)
                } else {
                    let (d, off) = parse_mem(toks[2], line)?;
                    let s = parse_reg(toks[3], line)?;
                    insn::atomic_insn(sz, d, s, off, aop)
                }));
            }
        }
        return aerr(line, format!("unknown atomic op '{}'", sub));
    }
    // xchgNN rS, [rD+off] — atomic exchange (old value lands in rS)
    for (m, sz) in [("xchg64", size::DW), ("xchg32", size::W)] {
        if mnemonic == m {
            if toks.len() != 3 {
                return aerr(line, format!("usage: {} rS, [rD+off]", m));
            }
            let s = parse_reg(toks[1], line)?;
            let (d, off) = parse_mem(toks[2], line)?;
            return Ok(Pending::Done(insn::atomic_insn(sz, d, s, off, insn::atomic::XCHG)));
        }
    }
    // cmpxchgNN [rD+off], rS — compare against r0, store rS on match;
    // the value observed in memory lands in r0 either way
    for (m, sz) in [("cmpxchg64", size::DW), ("cmpxchg32", size::W)] {
        if mnemonic == m {
            if toks.len() != 3 {
                return aerr(line, format!("usage: {} [rD+off], rS", m));
            }
            let (d, off) = parse_mem(toks[1], line)?;
            let s = parse_reg(toks[2], line)?;
            return Ok(Pending::Done(insn::atomic_insn(sz, d, s, off, insn::atomic::CMPXCHG)));
        }
    }
    match mnemonic {
        "lddw" => {
            let dst = parse_reg(toks[1], line)?;
            let v = parse_imm(toks[2], line)? as u64;
            Ok(Pending::Imm64 { dst, v })
        }
        "ldmap" => {
            if toks.len() != 3 {
                return aerr(line, "usage: ldmap rD, MAPNAME");
            }
            let dst = parse_reg(toks[1], line)?;
            Ok(Pending::MapRef { dst, map: toks[2].trim_end_matches(',').into() })
        }
        "ja" | "jmp" => {
            if toks.len() != 2 {
                return aerr(line, "usage: ja LABEL");
            }
            Ok(Pending::Branch {
                opcode: class::JMP | jmp::JA,
                dst: 0,
                src_reg: 0,
                imm: 0,
                label: toks[1].into(),
            })
        }
        "call" => {
            if toks.len() != 2 {
                return aerr(line, "usage: call HELPER_ID|helper_name|subprog_label");
            }
            let t = toks[1].trim_end_matches(',');
            if let Ok(v) = parse_imm(t, line) {
                Ok(Pending::Done(insn::call(v as i32)))
            } else if let Some(spec) = super::helpers::spec_by_name(t) {
                Ok(Pending::Done(insn::call(spec.id)))
            } else {
                // anything else is a bpf-to-bpf call to a label; the
                // label is resolved (or rejected) at finish time
                Ok(Pending::PseudoCall { label: t.to_string() })
            }
        }
        "exit" => Ok(Pending::Done(insn::exit())),
        m => {
            // conditional jumps: jOP (64-bit compare) / jOP32 (compare
            // on the low 32 bits, the BPF_JMP32 class)
            let (base, cls) = match m.strip_suffix("32") {
                Some(b) if jmp_op(b).is_some() => (b, class::JMP32),
                _ => (m, class::JMP),
            };
            if let Some(op) = jmp_op(base) {
                if toks.len() != 4 {
                    return aerr(line, format!("usage: {} rD, rS|imm, LABEL", m));
                }
                let dst = parse_reg(toks[1], line)?;
                let label = toks[3].to_string();
                if toks[2].starts_with('r') {
                    let s = parse_reg(toks[2], line)?;
                    Ok(Pending::Branch {
                        opcode: cls | src::X | op,
                        dst,
                        src_reg: s,
                        imm: 0,
                        label,
                    })
                } else {
                    let imm = parse_imm(toks[2], line)?;
                    Ok(Pending::Branch {
                        opcode: cls | src::K | op,
                        dst,
                        src_reg: 0,
                        imm: imm as i32,
                        label,
                    })
                }
            } else {
                aerr(line, format!("unknown mnemonic '{}'", m))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpf::insn::disasm;

    #[test]
    fn assemble_minimal() {
        let o = assemble("prog tuner t\n  mov64 r0, 0\n  exit\n").unwrap();
        assert_eq!(o.progs.len(), 1);
        assert_eq!(o.progs[0].insns.len(), 2);
    }

    #[test]
    fn assemble_with_map_and_labels() {
        let src = r#"
map latency_map array key=4 value=16 entries=64

prog tuner size_aware
  stw   [r10-4], 0
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, latency_map
  call  bpf_map_lookup_elem
  jne   r0, 0, not_null
  mov64 r0, 0
  exit
not_null:
  ldxdw r3, [r0+0]
  mov64 r0, 1
  exit
"#;
        let o = assemble(src).unwrap();
        assert_eq!(o.maps.len(), 1);
        let p = &o.progs[0];
        assert_eq!(p.relocs.len(), 1);
        // reloc points at the lddw slot
        assert!(p.insns[p.relocs[0].insn_idx as usize].is_lddw());
        let text = disasm(&p.insns);
        assert!(text.contains("call 1"), "{}", text);
        // jne target skips 2 insns (mov, exit)
        assert!(text.contains("jne r0, 0, +2"), "{}", text);
    }

    #[test]
    fn label_after_lddw_accounts_for_two_slots() {
        let src = r#"
prog tuner t
  lddw r1, 0x123456789
  jeq r1, 0, done
  mov64 r0, 1
  exit
done:
  mov64 r0, 0
  exit
"#;
        let o = assemble(src).unwrap();
        let insns = &o.progs[0].insns;
        // slots: 0-1 lddw, 2 jeq, 3 mov, 4 exit, 5 mov, 6 exit
        assert_eq!(insns.len(), 7);
        assert_eq!(insns[2].off, 2); // 2+1+2 = 5
    }

    #[test]
    fn assemble_ringbuf_map() {
        let o = assemble(
            "map events ringbuf entries=4096\nprog profiler p\n  mov64 r0, 0\n  exit\n",
        )
        .unwrap();
        assert_eq!(o.maps[0].kind, MapKind::RingBuf);
        assert_eq!(o.maps[0].key_size, 0);
        assert_eq!(o.maps[0].value_size, 0);
        assert_eq!(o.maps[0].max_entries, 4096);
        // non-power-of-two ring size is rejected by MapDef::validate
        let e = assemble("map ev ringbuf entries=100\n").unwrap_err();
        assert!(e.message.contains("power of two"), "{}", e.message);
    }

    #[test]
    fn assemble_subprog_call() {
        let src = r#"
prog tuner with_sub
  mov64 r1, 4
  mov64 r2, 5
  call  add_sub          ; bpf-to-bpf call to the label below
  exit
add_sub:
  mov64 r0, r1
  add64 r0, r2
  exit
"#;
        let o = assemble(src).unwrap();
        let insns = &o.progs[0].insns;
        assert!(insns[2].is_pseudo_call());
        // call at slot 2 targets slot 4: imm = 4 - 2 - 1 = 1
        assert_eq!(insns[2].imm, 1);
        let text = crate::bpf::insn::disasm(insns);
        assert!(text.contains("call +1"), "{}", text);
    }

    #[test]
    fn call_to_unknown_name_is_clean_error() {
        let e = assemble("prog tuner t\n  call nowhere\n  exit\n").unwrap_err();
        assert!(
            e.message.contains("neither a helper name nor a defined label"),
            "{}",
            e.message
        );
    }

    #[test]
    fn duplicate_subprog_label_rejected() {
        // two subprograms under one name would silently bind the call
        // to whichever survived — must be a hard error instead
        let src = "prog tuner t\n  call sub\n  exit\nsub:\n  exit\nsub:\n  exit\n";
        let e = assemble(src).unwrap_err();
        assert!(e.message.contains("duplicate"), "{}", e.message);
    }

    #[test]
    fn assemble_progarray_map() {
        let o = assemble(
            "map chain progarray entries=4\nprog tuner t\n  mov64 r0, 0\n  exit\n",
        )
        .unwrap();
        assert_eq!(o.maps[0].kind, MapKind::ProgArray);
        assert_eq!(o.maps[0].key_size, 4);
        assert_eq!(o.maps[0].value_size, 4);
        assert_eq!(o.maps[0].max_entries, 4);
    }

    #[test]
    fn assemble_jmp32_mnemonics() {
        use crate::bpf::insn::{class, disasm_one, jmp, src};
        let src_text = r#"
prog tuner t
  jlt32 r1, 5, done
  jsgt32 r1, r2, done
  jeq   r1, 0, done
done:
  mov64 r0, 0
  exit
"#;
        let o = assemble(src_text).unwrap();
        let insns = &o.progs[0].insns;
        assert_eq!(insns[0].opcode, class::JMP32 | src::K | jmp::JLT);
        assert_eq!(insns[0].imm, 5);
        assert_eq!(insns[1].opcode, class::JMP32 | src::X | jmp::JSGT);
        assert_eq!(insns[1].src, 2);
        assert_eq!(insns[2].opcode, class::JMP | src::K | jmp::JEQ);
        // jmp32 disasm carries the 32 suffix and reassembles
        assert!(disasm_one(&insns[0], None).starts_with("jlt32 r1, 5"));
        assert!(disasm_one(&insns[1], None).starts_with("jsgt32 r1, r2"));
    }

    #[test]
    fn assemble_atomics_roundtrip_through_disasm() {
        use crate::bpf::insn::{atomic, disasm_one, size};
        let src = r#"
prog tuner t
  lock add64 [r1+8], r2
  lock fetchadd32 r3, [r1+4]
  lock xor64 [r1+0], r4
  xchg64 r2, [r1+16]
  cmpxchg32 [r1+4], r5
  mov64 r0, 0
  exit
"#;
        let o = assemble(src).unwrap();
        let insns = &o.progs[0].insns;
        assert_eq!(insns[0], crate::bpf::insn::atomic_insn(size::DW, 1, 2, 8, atomic::ADD));
        assert_eq!(
            insns[1],
            crate::bpf::insn::atomic_insn(size::W, 1, 3, 4, atomic::ADD | atomic::FETCH)
        );
        assert_eq!(insns[2], crate::bpf::insn::atomic_insn(size::DW, 1, 4, 0, atomic::XOR));
        assert_eq!(insns[3], crate::bpf::insn::atomic_insn(size::DW, 1, 2, 16, atomic::XCHG));
        assert_eq!(insns[4], crate::bpf::insn::atomic_insn(size::W, 1, 5, 4, atomic::CMPXCHG));
        // every atomic disassembles back to text this assembler accepts
        for ins in &insns[..5] {
            let text = format!("prog tuner t\n  {}\n  exit\n", disasm_one(ins, None));
            let back = assemble(&text).unwrap();
            assert_eq!(&back.progs[0].insns[0], ins, "{}", text);
        }
    }

    #[test]
    fn atomic_parse_errors() {
        assert!(assemble("prog tuner t\n  lock sub64 [r1+0], r2\n  exit\n")
            .unwrap_err()
            .message
            .contains("unknown atomic op"));
        assert!(assemble("prog tuner t\n  lock add64\n  exit\n")
            .unwrap_err()
            .message
            .contains("usage: lock"));
        assert!(assemble("prog tuner t\n  cmpxchg64 r1, r2\n  exit\n").is_err());
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = assemble("prog tuner t\n  bogus r0\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.message.contains("bogus"));
    }

    #[test]
    fn undefined_label() {
        let e = assemble("prog tuner t\n  ja nowhere\n  exit\n").unwrap_err();
        assert!(e.message.contains("undefined label"));
    }

    #[test]
    fn duplicate_label() {
        let e = assemble("prog tuner t\nl:\nl:\n  exit\n").unwrap_err();
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn negative_offsets_and_hex() {
        let o = assemble("prog tuner t\n  ldxw r2, [r10-8]\n  mov64 r0, 0x2a\n  exit\n").unwrap();
        assert_eq!(o.progs[0].insns[0].off, -8);
        assert_eq!(o.progs[0].insns[1].imm, 42);
    }

    #[test]
    fn multiple_progs_in_one_object() {
        let src = "prog profiler p\n  mov64 r0, 0\n  exit\nprog tuner t\n  mov64 r0, 1\n  exit\n";
        let o = assemble(src).unwrap();
        assert_eq!(o.progs.len(), 2);
        assert!(o.prog_by_section("profiler").is_some());
        assert!(o.prog_by_section("tuner").is_some());
    }
}
