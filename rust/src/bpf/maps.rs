//! Typed eBPF maps: the structured cross-plugin state-sharing substrate.
//!
//! Four map kinds are provided, mirroring the kernel/bpftime types the
//! paper relies on:
//!
//! - [`MapKind::Array`] — fixed `max_entries`, 4-byte index key, O(1)
//!   lookup (the paper notes array maps are faster than hash maps; the
//!   Table 1 bench measures both).
//! - [`MapKind::Hash`] — open-addressed, fixed capacity, arbitrary
//!   fixed-size keys.
//! - [`MapKind::PerCpuArray`] — one array instance per logical cpu
//!   (here: per registered thread slot), no cross-thread contention.
//! - [`MapKind::RingBuf`] — a power-of-two MPSC byte ring with
//!   kernel-compatible record framing, the structured event-streaming
//!   channel behind `bpf_ringbuf_*` (verified policies produce; one
//!   host consumer drains).
//!
//! Semantics follow eBPF: `lookup` returns a *stable raw pointer* into
//! map storage (valid for the map's lifetime — storage is allocated once
//! and never reallocated), through which verified programs read and
//! write directly. Word-level atomicity across concurrent writers is not
//! guaranteed (as in kernel BPF); structural operations (insert/delete)
//! are serialized by a per-map spinlock. This is exactly the concurrency
//! contract the paper's T2 tension describes: structured, fixed-size
//! state with atomic element access replacing ad hoc shared memory.

use super::stats::{MapPressure, MapPressureStats};
use std::cell::UnsafeCell;
use std::collections::HashMap as StdHashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// Map type discriminator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapKind {
    /// fixed-size array, 4-byte index key, O(1) lookup
    Array,
    /// open-addressed hash map, arbitrary fixed-size keys
    Hash,
    /// one array instance per logical cpu slot
    PerCpuArray,
    /// MPSC byte ring with kernel-compatible record framing
    RingBuf,
    /// array of verified program handles, the `bpf_tail_call` jump
    /// table: slots hold same-typed programs and are replaced
    /// atomically (the composable-chain hot-reload mechanism)
    ProgArray,
}

impl MapKind {
    /// Decode the kernel `bpf_map_type` numbering used on the wire.
    pub fn from_u32(v: u32) -> Option<MapKind> {
        match v {
            1 => Some(MapKind::Hash),
            2 => Some(MapKind::Array),
            3 => Some(MapKind::ProgArray),
            6 => Some(MapKind::PerCpuArray),
            27 => Some(MapKind::RingBuf),
            _ => None,
        }
    }
    /// Kernel `bpf_map_type` id for this kind.
    pub fn to_u32(self) -> u32 {
        match self {
            MapKind::Hash => 1,
            MapKind::Array => 2,
            MapKind::ProgArray => 3,
            MapKind::PerCpuArray => 6,
            MapKind::RingBuf => 27,
        }
    }
}

/// Static definition of a map (what a BPF object file declares).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MapDef {
    /// map name — the cross-object sharing key in a [`MapRegistry`]
    pub name: String,
    /// map type
    pub kind: MapKind,
    /// key size in bytes (4 for arrays; 0 for ringbuf/prog-array convention aside)
    pub key_size: u32,
    /// value size in bytes (0 for ringbuf)
    pub value_size: u32,
    /// capacity: entries for element maps, data bytes for ringbufs,
    /// slots for prog arrays
    pub max_entries: u32,
}

impl MapDef {
    /// Kind-specific structural validation (sizes, power-of-two rings).
    pub fn validate(&self) -> Result<(), String> {
        if self.max_entries == 0 {
            return Err(format!("map '{}': max_entries must be > 0", self.name));
        }
        if self.kind == MapKind::ProgArray {
            // kernel prog-array ABI: 4-byte index key, 4-byte (fd) value
            if self.key_size != 4 || self.value_size != 4 {
                return Err(format!(
                    "map '{}': prog arrays require key_size == 4 and value_size == 4 \
                     (got key={} value={})",
                    self.name, self.key_size, self.value_size
                ));
            }
            if self.max_entries > 1024 {
                return Err(format!(
                    "map '{}': prog arrays support at most 1024 slots (got {})",
                    self.name, self.max_entries
                ));
            }
            return Ok(());
        }
        if self.kind == MapKind::RingBuf {
            // kernel semantics: max_entries is the data size in bytes,
            // power of two; key/value sizes must be 0.
            if self.key_size != 0 || self.value_size != 0 {
                return Err(format!(
                    "map '{}': ringbuf maps take no key/value sizes (got key={} value={})",
                    self.name, self.key_size, self.value_size
                ));
            }
            if !self.max_entries.is_power_of_two() || self.max_entries < 64 {
                return Err(format!(
                    "map '{}': ringbuf size must be a power of two >= 64 (got {})",
                    self.name, self.max_entries
                ));
            }
            return Ok(());
        }
        if self.value_size == 0 || self.value_size > 64 * 1024 {
            return Err(format!("map '{}': invalid value_size {}", self.name, self.value_size));
        }
        match self.kind {
            MapKind::Array | MapKind::PerCpuArray => {
                if self.key_size != 4 {
                    return Err(format!(
                        "map '{}': array maps require key_size == 4 (got {})",
                        self.name, self.key_size
                    ));
                }
            }
            MapKind::Hash => {
                if self.key_size == 0 || self.key_size > 512 {
                    return Err(format!("map '{}': invalid key_size {}", self.name, self.key_size));
                }
            }
            MapKind::RingBuf | MapKind::ProgArray => unreachable!(),
        }
        Ok(())
    }
}

/// Number of per-cpu slots for `PerCpuArray`.
pub const NCPU: usize = 16;

const SLOT_EMPTY: u8 = 0;
const SLOT_FULL: u8 = 1;
const SLOT_TOMBSTONE: u8 = 2;

// -- ringbuf record framing (kernel-compatible) -------------------------------
//
// Every record is prefixed by an 8-byte header: a u32 length word whose
// top two bits are flags, then a u32 the kernel uses for the page
// offset (always 0 here). Positions are logical (monotonic u64) and
// advance in 8-byte steps, so headers are always 8-aligned.

/// Header bit: record reserved but not yet submitted/discarded.
pub const RINGBUF_BUSY_BIT: u32 = 1 << 31;
/// Header bit: record was discarded; the consumer skips its payload.
pub const RINGBUF_DISCARD_BIT: u32 = 1 << 30;
/// Mask of the payload-length bits in the header word.
pub const RINGBUF_LEN_MASK: u32 = RINGBUF_DISCARD_BIT - 1;
/// Bytes of framing prepended to every record.
pub const RINGBUF_HDR_SIZE: u64 = 8;

/// `bpf_ringbuf_query` flag values (kernel numbering).
pub mod ringbuf_query {
    /// unconsumed bytes between producer and consumer
    pub const AVAIL_DATA: u64 = 0;
    /// ring data size in bytes
    pub const RING_SIZE: u64 = 1;
    /// logical consumer position
    pub const CONS_POS: u64 = 2;
    /// logical producer position
    pub const PROD_POS: u64 = 3;
}

#[inline]
fn round_up8(v: u64) -> u64 {
    (v + 7) & !7
}

/// Ring-buffer state: the byte storage plus monotonic producer /
/// consumer positions and the counters behind the event-conservation
/// invariant `drained + dropped + discarded == emitted`.
struct RingState {
    /// data size in bytes (power of two); physical offset = pos & mask
    mask: u64,
    /// ring bytes as 8-byte words — guarantees the 8-aligned record
    /// headers really are aligned for the AtomicU32 overlay (a plain
    /// byte allocation only promises 1-byte alignment), and doubles as
    /// the data area + the equally sized slack region a
    /// boundary-crossing record spills into (see [`Map::new`])
    data: Box<[AtomicU64]>,
    producer: AtomicU64,
    consumer: AtomicU64,
    /// producer-side failed reservations
    drops: AtomicU64,
    /// consumer-side records skipped because the producer discarded them
    discards: AtomicU64,
    /// successfully reserved records (later submitted *or* discarded)
    emitted: AtomicU64,
    /// consumer-side records delivered to a drain callback
    drained: AtomicU64,
    /// deepest unconsumed backlog in bytes ever observed at reserve time
    hiwater: AtomicU64,
}

impl RingState {
    fn new(size_bytes: u32) -> RingState {
        let words = size_bytes as usize * 2 / 8;
        let mut data = Vec::with_capacity(words);
        data.resize_with(words, || AtomicU64::new(0));
        RingState {
            mask: size_bytes as u64 - 1,
            data: data.into_boxed_slice(),
            producer: AtomicU64::new(0),
            consumer: AtomicU64::new(0),
            drops: AtomicU64::new(0),
            discards: AtomicU64::new(0),
            emitted: AtomicU64::new(0),
            drained: AtomicU64::new(0),
            hiwater: AtomicU64::new(0),
        }
    }

    /// Byte pointer at logical position `pos` (records may extend into
    /// the slack region [size, 2*size)).
    #[inline]
    fn byte_ptr(&self, pos: u64) -> *mut u8 {
        // AtomicU64 wraps an UnsafeCell, so mutating through a pointer
        // derived from &self is sound.
        unsafe { (self.data.as_ptr() as *mut u8).add((pos & self.mask) as usize) }
    }

    /// The record header at `pos`. Positions advance in 8-byte steps
    /// from 0 over 8-aligned storage, so the overlay is well-formed.
    #[inline]
    fn hdr(&self, pos: u64) -> &AtomicU32 {
        unsafe { &*(self.byte_ptr(pos) as *const AtomicU32) }
    }
}

/// One occupied slot of a [`MapKind::ProgArray`] map: a verified
/// program handle plus its program-type tag. The handle is stored
/// type-erased so the map layer stays independent of the program
/// loader; [`crate::bpf::program`] owns the only (down)cast sites.
#[derive(Clone)]
pub struct ProgSlot {
    /// program-type tag ([`crate::bpf::helpers::ProgType::tag`]): all
    /// occupied slots of one prog array must share it
    pub tag: u32,
    /// the installed program (`Arc<LoadedProgram>` behind `dyn Any`)
    pub handle: Arc<dyn std::any::Any + Send + Sync>,
}

/// A live map instance. Storage is allocated once at creation so value
/// pointers handed to programs remain valid for the map's lifetime.
pub struct Map {
    /// the definition this map was created from
    pub def: MapDef,
    /// registry-assigned live id (what `lddw rX, map[id]` resolves to)
    pub id: u32,
    /// value storage: max_entries * value_size (× NCPU for per-cpu);
    /// 8-aligned so verified atomic instructions can overlay
    /// `AtomicU32`/`AtomicU64` on any naturally-aligned offset.
    values: AlignedBytes,
    /// hash maps only: key storage, max_entries * key_size.
    keys: Box<[UnsafeCell<u8>]>,
    /// hash maps only: slot occupancy flags.
    slots: Box<[AtomicU8]>,
    /// hash maps only: live element count.
    count: AtomicU32,
    /// ringbuf maps only: positions + drop accounting.
    ring: Option<RingState>,
    /// prog-array maps only: the tail-call jump table. One mutex over
    /// the whole table: writers (slot replacement) are rare
    /// control-plane events, readers clone one `Arc` per tail call.
    progs: Option<Mutex<Vec<Option<ProgSlot>>>>,
    /// serializes structural changes (hash insert/delete, ring reserve).
    lock: SpinLock,
    /// always-on striped operation counters (lookups/updates/deletes/
    /// tombstone churn) — the `ncclbpf stats` map-pressure rows.
    pressure: MapPressure,
}

// SAFETY: concurrent byte-level access to `values` is the documented eBPF
// map contract (verified programs may race on value bytes, as in the
// kernel); structural metadata uses atomics / the spinlock.
unsafe impl Send for Map {}
unsafe impl Sync for Map {}

struct SpinLock(AtomicBool);
impl SpinLock {
    fn new() -> Self {
        SpinLock(AtomicBool::new(false))
    }
    fn lock(&self) {
        while self
            .0
            .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            std::hint::spin_loop();
        }
    }
    fn unlock(&self) {
        self.0.store(false, Ordering::Release);
    }
}

/// Poison-recovering lock over a prog array's slot table (same policy
/// as `host::reload`: a panicking writer must not wedge the table).
fn lock_progs(
    m: &Mutex<Vec<Option<ProgSlot>>>,
) -> std::sync::MutexGuard<'_, Vec<Option<ProgSlot>>> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn zeroed_cells(n: usize) -> Box<[UnsafeCell<u8>]> {
    let mut v = Vec::with_capacity(n);
    v.resize_with(n, || UnsafeCell::new(0u8));
    v.into_boxed_slice()
}

/// Zero-initialized byte storage with guaranteed 8-byte alignment
/// (u64 words under the hood). A plain `Box<[UnsafeCell<u8>]>` only
/// promises 1-byte alignment, but the atomic instruction class
/// overlays `AtomicU32`/`AtomicU64` onto map-value memory — both the
/// interpreter and the JIT's `lock`-prefixed ops require the base to
/// be naturally aligned so the verifier's offset-alignment rule
/// (relative to this base) is sufficient.
pub(crate) struct AlignedBytes {
    words: Box<[UnsafeCell<u64>]>,
    len: usize,
}

impl AlignedBytes {
    fn zeroed(len: usize) -> AlignedBytes {
        let mut v = Vec::with_capacity(len.div_ceil(8));
        v.resize_with(len.div_ceil(8), || UnsafeCell::new(0u64));
        AlignedBytes { words: v.into_boxed_slice(), len }
    }

    /// Base byte pointer (8-aligned, stable for the map's lifetime).
    #[inline]
    fn as_ptr(&self) -> *mut u8 {
        self.words.as_ptr() as *mut u8
    }

    #[inline]
    fn len(&self) -> usize {
        self.len
    }
}

impl Map {
    /// Allocate a map instance for `def` under registry id `id`.
    pub fn new(def: MapDef, id: u32) -> Result<Map, String> {
        def.validate()?;
        let values = match def.kind {
            // ring storage lives in RingState (8-aligned words): the
            // data area + an equally sized slack region a
            // boundary-crossing record writes contiguously into
            // (emulating the kernel's double-mapped pages), so producer
            // and consumer never have to split a record.
            MapKind::RingBuf => AlignedBytes::zeroed(0),
            // prog-array slots live in `progs`, not byte storage
            MapKind::ProgArray => AlignedBytes::zeroed(0),
            MapKind::PerCpuArray => {
                AlignedBytes::zeroed(def.max_entries as usize * NCPU * def.value_size as usize)
            }
            _ => AlignedBytes::zeroed(def.max_entries as usize * def.value_size as usize),
        };
        let (keys, slots) = if def.kind == MapKind::Hash {
            let keys = zeroed_cells(def.max_entries as usize * def.key_size as usize);
            let mut s = Vec::with_capacity(def.max_entries as usize);
            s.resize_with(def.max_entries as usize, || AtomicU8::new(SLOT_EMPTY));
            (keys, s.into_boxed_slice())
        } else {
            (zeroed_cells(0), Vec::new().into_boxed_slice())
        };
        let ring = (def.kind == MapKind::RingBuf).then(|| RingState::new(def.max_entries));
        let progs = (def.kind == MapKind::ProgArray)
            .then(|| Mutex::new((0..def.max_entries).map(|_| None).collect()));
        Ok(Map {
            def,
            id,
            values,
            keys,
            slots,
            count: AtomicU32::new(0),
            ring,
            progs,
            lock: SpinLock::new(),
            pressure: MapPressure::default(),
        })
    }

    #[inline]
    fn value_ptr_at(&self, index: usize) -> *mut u8 {
        debug_assert!((index + 1) * self.def.value_size as usize <= self.values.len());
        unsafe { self.values.as_ptr().add(index * self.def.value_size as usize) }
    }

    /// Base pointer of the contiguous value storage (`Array` /
    /// `PerCpuArray` element 0). Storage is allocated once at creation
    /// and never reallocated, so the pointer is stable for the map's
    /// lifetime — the contract that lets the JIT embed it as an
    /// immediate in inlined array-lookup code (the emitted code is
    /// owned by a `LoadedProgram` that also owns an `Arc` to this map).
    #[inline]
    pub(crate) fn value_base_ptr(&self) -> *mut u8 {
        self.values.as_ptr()
    }

    #[inline]
    fn key_ptr_at(&self, slot: usize) -> *mut u8 {
        unsafe { self.keys.as_ptr().add(slot * self.def.key_size as usize) as *mut u8 }
    }

    /// FNV-1a over key bytes.
    #[inline]
    fn hash_key(key: &[u8]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in key {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    }

    /// Current logical cpu slot for per-cpu maps.
    #[inline]
    pub fn current_cpu() -> usize {
        thread_cpu_slot()
    }

    /// Look up `key`; returns a stable pointer to the value or null.
    /// This is the hot path behind `bpf_map_lookup_elem`.
    pub fn lookup(&self, key: &[u8]) -> *mut u8 {
        self.pressure.record_lookup();
        if key.len() != self.def.key_size as usize {
            return std::ptr::null_mut();
        }
        match self.def.kind {
            // ringbufs and prog arrays have no data elements to point at
            MapKind::RingBuf | MapKind::ProgArray => std::ptr::null_mut(),
            MapKind::Array => {
                let idx = u32::from_le_bytes(key.try_into().unwrap()) as usize;
                if idx >= self.def.max_entries as usize {
                    return std::ptr::null_mut();
                }
                self.value_ptr_at(idx)
            }
            MapKind::PerCpuArray => {
                let idx = u32::from_le_bytes(key.try_into().unwrap()) as usize;
                if idx >= self.def.max_entries as usize {
                    return std::ptr::null_mut();
                }
                self.value_ptr_at(Self::current_cpu() * self.def.max_entries as usize + idx)
            }
            MapKind::Hash => {
                let cap = self.def.max_entries as usize;
                let mut slot = (Self::hash_key(key) % cap as u64) as usize;
                for _ in 0..cap {
                    match self.slots[slot].load(Ordering::Acquire) {
                        SLOT_EMPTY => return std::ptr::null_mut(),
                        SLOT_FULL => {
                            if self.key_eq(slot, key) {
                                return self.value_ptr_at(slot);
                            }
                        }
                        _ => {} // tombstone: keep probing
                    }
                    slot = (slot + 1) % cap;
                }
                std::ptr::null_mut()
            }
        }
    }

    #[inline]
    fn key_eq(&self, slot: usize, key: &[u8]) -> bool {
        let p = self.key_ptr_at(slot);
        let stored = unsafe { std::slice::from_raw_parts(p, self.def.key_size as usize) };
        stored == key
    }

    /// Insert or overwrite. Returns Err if the (hash) map is full.
    pub fn update(&self, key: &[u8], value: &[u8]) -> Result<(), String> {
        self.pressure.record_update();
        if key.len() != self.def.key_size as usize {
            return Err(format!("map '{}': bad key size {}", self.def.name, key.len()));
        }
        if value.len() != self.def.value_size as usize {
            return Err(format!("map '{}': bad value size {}", self.def.name, value.len()));
        }
        match self.def.kind {
            MapKind::RingBuf => {
                Err(format!("map '{}': ringbuf maps have no update", self.def.name))
            }
            MapKind::ProgArray => Err(format!(
                "map '{}': prog-array slots hold programs, not bytes \
                 (use prog_array_set)",
                self.def.name
            )),
            MapKind::Array | MapKind::PerCpuArray => {
                let p = self.lookup(key);
                if p.is_null() {
                    return Err(format!("map '{}': index out of range", self.def.name));
                }
                unsafe { std::ptr::copy_nonoverlapping(value.as_ptr(), p, value.len()) };
                Ok(())
            }
            MapKind::Hash => {
                self.lock.lock();
                let r = self.hash_insert(key, value);
                self.lock.unlock();
                r
            }
        }
    }

    fn hash_insert(&self, key: &[u8], value: &[u8]) -> Result<(), String> {
        let cap = self.def.max_entries as usize;
        let mut slot = (Self::hash_key(key) % cap as u64) as usize;
        let mut first_free: Option<usize> = None;
        for _ in 0..cap {
            match self.slots[slot].load(Ordering::Acquire) {
                SLOT_FULL => {
                    if self.key_eq(slot, key) {
                        unsafe {
                            std::ptr::copy_nonoverlapping(
                                value.as_ptr(),
                                self.value_ptr_at(slot),
                                value.len(),
                            )
                        };
                        return Ok(());
                    }
                }
                SLOT_EMPTY => {
                    let free = first_free.unwrap_or(slot);
                    if first_free.is_some() {
                        self.pressure.record_tombstone(); // reused one
                    }
                    return self.fill_slot(free, key, value);
                }
                _ => {
                    if first_free.is_none() {
                        first_free = Some(slot);
                    }
                }
            }
            slot = (slot + 1) % cap;
        }
        if let Some(free) = first_free {
            self.pressure.record_tombstone(); // reused one
            return self.fill_slot(free, key, value);
        }
        Err(format!("map '{}' full ({} entries)", self.def.name, cap))
    }

    fn fill_slot(&self, slot: usize, key: &[u8], value: &[u8]) -> Result<(), String> {
        unsafe {
            std::ptr::copy_nonoverlapping(key.as_ptr(), self.key_ptr_at(slot), key.len());
            std::ptr::copy_nonoverlapping(value.as_ptr(), self.value_ptr_at(slot), value.len());
        }
        self.slots[slot].store(SLOT_FULL, Ordering::Release);
        self.count.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Delete `key` (hash maps only; arrays cannot delete). Ok(true) if removed.
    pub fn delete(&self, key: &[u8]) -> Result<bool, String> {
        self.pressure.record_delete();
        match self.def.kind {
            MapKind::Array | MapKind::PerCpuArray | MapKind::RingBuf | MapKind::ProgArray => {
                Err(format!("map '{}': delete unsupported on this map kind", self.def.name))
            }
            MapKind::Hash => {
                if key.len() != self.def.key_size as usize {
                    return Ok(false);
                }
                self.lock.lock();
                let cap = self.def.max_entries as usize;
                let mut slot = (Self::hash_key(key) % cap as u64) as usize;
                let mut removed = false;
                for _ in 0..cap {
                    match self.slots[slot].load(Ordering::Acquire) {
                        SLOT_EMPTY => break,
                        SLOT_FULL if self.key_eq(slot, key) => {
                            self.slots[slot].store(SLOT_TOMBSTONE, Ordering::Release);
                            self.count.fetch_sub(1, Ordering::Relaxed);
                            self.pressure.record_tombstone(); // left one
                            removed = true;
                            break;
                        }
                        _ => {}
                    }
                    slot = (slot + 1) % cap;
                }
                self.lock.unlock();
                Ok(removed)
            }
        }
    }

    /// Number of live entries (hash), unconsumed bytes (ringbuf), or
    /// max_entries (arrays).
    pub fn len(&self) -> usize {
        match self.def.kind {
            MapKind::Hash => self.count.load(Ordering::Relaxed) as usize,
            MapKind::RingBuf => self.ringbuf_query(ringbuf_query::AVAIL_DATA) as usize,
            MapKind::ProgArray => self
                .progs
                .as_ref()
                .map(|p| lock_progs(p).iter().filter(|s| s.is_some()).count())
                .unwrap_or(0),
            _ => self.def.max_entries as usize,
        }
    }

    /// True when [`Map::len`] is zero.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // -- prog arrays (MapKind::ProgArray) -------------------------------------

    /// Install `slot` at `index`, replacing any previous occupant
    /// atomically (in-flight tail calls keep their `Arc` to the old
    /// program; the next call observes the new one — the same
    /// grace-period shape as [`crate::host::reload`]). All occupied
    /// slots must share one program-type tag: the first insert pins it,
    /// and a mismatched tag is rejected so a chain can never dispatch
    /// into a program verified against a different ctx layout.
    pub fn prog_array_set(&self, index: u32, slot: ProgSlot) -> Result<(), String> {
        let Some(progs) = &self.progs else {
            return Err(format!("map '{}' is not a prog array", self.def.name));
        };
        if index >= self.def.max_entries {
            return Err(format!(
                "map '{}': slot {} out of range (entries {})",
                self.def.name, index, self.def.max_entries
            ));
        }
        let mut g = lock_progs(progs);
        if let Some(other) = g.iter().flatten().find(|s| s.tag != slot.tag) {
            return Err(format!(
                "map '{}': program type tag {} is incompatible with the array's \
                 installed type tag {} (all slots of a prog array must hold the \
                 same program type)",
                self.def.name, slot.tag, other.tag
            ));
        }
        g[index as usize] = Some(slot);
        Ok(())
    }

    /// Read slot `index` (a cheap `Arc` clone). `None` for empty or
    /// out-of-range slots — the tail-call fallthrough path.
    pub fn prog_array_get(&self, index: u32) -> Option<ProgSlot> {
        let progs = self.progs.as_ref()?;
        if index >= self.def.max_entries {
            return None;
        }
        lock_progs(progs)[index as usize].clone()
    }

    /// Empty slot `index`; returns true if a program was installed.
    pub fn prog_array_clear(&self, index: u32) -> bool {
        let Some(progs) = &self.progs else { return false };
        if index >= self.def.max_entries {
            return false;
        }
        lock_progs(progs)[index as usize].take().is_some()
    }

    /// Typed convenience: read the value for `key` as a copy.
    pub fn read_value(&self, key: &[u8]) -> Option<Vec<u8>> {
        let p = self.lookup(key);
        if p.is_null() {
            return None;
        }
        let mut out = vec![0u8; self.def.value_size as usize];
        unsafe { std::ptr::copy_nonoverlapping(p, out.as_mut_ptr(), out.len()) };
        Some(out)
    }

    /// Typed convenience for the common u32-key / u64-value policy state.
    pub fn read_u64(&self, key: u32) -> Option<u64> {
        let v = self.read_value(&key.to_le_bytes())?;
        if v.len() < 8 {
            return None;
        }
        Some(u64::from_le_bytes(v[..8].try_into().unwrap()))
    }

    /// Typed convenience: write `value` into the first 8 value bytes.
    pub fn write_u64(&self, key: u32, value: u64) -> Result<(), String> {
        let mut buf = vec![0u8; self.def.value_size as usize];
        if buf.len() < 8 {
            return Err("value_size < 8".into());
        }
        buf[..8].copy_from_slice(&value.to_le_bytes());
        self.update(&key.to_le_bytes(), &buf)
    }

    // -- host-side per-cpu semantics ------------------------------------------
    //
    // BPF-side helpers (`bpf_map_update_elem` from a program) touch only
    // the calling thread's cpu slot, matching kernel semantics. The
    // host/control plane is the *userspace* side of that contract: a
    // kernel userspace update writes every cpu's slot, and a userspace
    // read returns all of them. The seed routed control-plane writes
    // through `update`, so any policy keeping state in a per-cpu map
    // (the traffic engine's counter programs; an slo_enforcer-style
    // target written per-thread) read a host-seeded value only on the
    // one thread that happened to share the writer's slot — 0 elsewhere.

    /// Control-plane update: write `value` into **all** cpu slots of a
    /// per-cpu map (kernel userspace semantics). Falls through to the
    /// plain update for non-per-cpu maps.
    pub fn update_all_cpus(&self, key: &[u8], value: &[u8]) -> Result<(), String> {
        if self.def.kind != MapKind::PerCpuArray {
            return self.update(key, value);
        }
        if key.len() != self.def.key_size as usize {
            return Err(format!("map '{}': bad key size {}", self.def.name, key.len()));
        }
        if value.len() != self.def.value_size as usize {
            return Err(format!("map '{}': bad value size {}", self.def.name, value.len()));
        }
        let idx = u32::from_le_bytes(key.try_into().unwrap()) as usize;
        if idx >= self.def.max_entries as usize {
            return Err(format!("map '{}': index out of range", self.def.name));
        }
        for cpu in 0..NCPU {
            let p = self.value_ptr_at(cpu * self.def.max_entries as usize + idx);
            unsafe { std::ptr::copy_nonoverlapping(value.as_ptr(), p, value.len()) };
        }
        Ok(())
    }

    /// Control-plane `write_u64` across all cpu slots.
    pub fn write_u64_all(&self, key: u32, value: u64) -> Result<(), String> {
        let mut buf = vec![0u8; self.def.value_size as usize];
        if buf.len() < 8 {
            return Err("value_size < 8".into());
        }
        buf[..8].copy_from_slice(&value.to_le_bytes());
        self.update_all_cpus(&key.to_le_bytes(), &buf)
    }

    /// Read one cpu slot of a per-cpu map (`read_u64` on non-per-cpu).
    pub fn read_u64_cpu(&self, key: u32, cpu: usize) -> Option<u64> {
        if self.def.kind != MapKind::PerCpuArray {
            return self.read_u64(key);
        }
        let idx = key as usize;
        if idx >= self.def.max_entries as usize || cpu >= NCPU || self.def.value_size < 8 {
            return None;
        }
        let p = self.value_ptr_at(cpu * self.def.max_entries as usize + idx);
        let mut b = [0u8; 8];
        unsafe { std::ptr::copy_nonoverlapping(p, b.as_mut_ptr(), 8) };
        Some(u64::from_le_bytes(b))
    }

    /// Aggregate a u64 counter across all cpu slots (sum) — the host
    /// observability path for per-cpu counters. `read_u64` on
    /// non-per-cpu maps.
    pub fn read_u64_all(&self, key: u32) -> Option<u64> {
        if self.def.kind != MapKind::PerCpuArray {
            return self.read_u64(key);
        }
        let mut total = 0u64;
        for cpu in 0..NCPU {
            total = total.wrapping_add(self.read_u64_cpu(key, cpu)?);
        }
        Some(total)
    }

    /// True iff `ptr` points into this map's value or ring storage
    /// (used by the runtime to sanity-check helper arguments in debug
    /// builds).
    pub fn contains_ptr(&self, ptr: *const u8) -> bool {
        let base = self.values.as_ptr() as usize;
        let end = base + self.values.len();
        if (ptr as usize) >= base && (ptr as usize) < end {
            return true;
        }
        if let Some(ring) = &self.ring {
            let base = ring.data.as_ptr() as usize;
            let end = base + ring.data.len() * 8;
            return (ptr as usize) >= base && (ptr as usize) < end;
        }
        false
    }

    // -- ring buffer (MapKind::RingBuf) ---------------------------------------
    //
    // Kernel-shaped MPSC ring: reservation is serialized by the per-map
    // spinlock over a handful of instructions (exactly like the
    // kernel's BPF ringbuf producer lock); commit (submit/discard) is a
    // single release-store on the record header, and the single
    // consumer drains lock-free with acquire loads. Memory ordering:
    //
    // - reserve: header BUSY store, then `producer` release-store — a
    //   consumer that observes the advanced producer position also
    //   observes the BUSY header.
    // - submit: release-store of the final header word — a consumer
    //   whose acquire load sees BUSY cleared also sees every payload
    //   byte the producer wrote.
    // - drain: `consumer` release-store after the callback — a producer
    //   whose reserve (acquire load of `consumer`) sees the freed space
    //   cannot overwrite bytes the consumer is still reading.

    /// Reserve `size` payload bytes in the ring; returns a pointer to
    /// the payload (header excluded) or null when the ring is full /
    /// the size is invalid. Every failed reservation counts as a drop.
    pub fn ringbuf_reserve(&self, size: u64) -> *mut u8 {
        let Some(ring) = &self.ring else { return std::ptr::null_mut() };
        let ring_size = self.def.max_entries as u64;
        let total = RINGBUF_HDR_SIZE + round_up8(size);
        if size == 0 || size > RINGBUF_LEN_MASK as u64 || total > ring_size {
            ring.drops.fetch_add(1, Ordering::Relaxed);
            return std::ptr::null_mut();
        }
        self.lock.lock();
        let prod = ring.producer.load(Ordering::Relaxed);
        let cons = ring.consumer.load(Ordering::Acquire);
        if prod + total - cons > ring_size {
            self.lock.unlock();
            ring.drops.fetch_add(1, Ordering::Relaxed);
            return std::ptr::null_mut();
        }
        // header: BUSY | len, pg_off word zeroed
        ring.hdr(prod).store(size as u32 | RINGBUF_BUSY_BIT, Ordering::Relaxed);
        unsafe {
            (ring.byte_ptr(prod).add(4) as *mut u32).write_unaligned(0);
        }
        ring.producer.store(prod + total, Ordering::Release);
        // backlog accounting under the same lock: emitted records and
        // the deepest unconsumed-byte watermark ever observed
        ring.emitted.fetch_add(1, Ordering::Relaxed);
        ring.hiwater.fetch_max(prod + total - cons, Ordering::Relaxed);
        self.lock.unlock();
        unsafe { ring.byte_ptr(prod).add(RINGBUF_HDR_SIZE as usize) }
    }

    /// Commit a reserved record: clear BUSY with a release-store so the
    /// consumer observes the payload. Takes only the record pointer —
    /// the header sits 8 bytes below it (kernel calling convention).
    ///
    /// # Safety
    /// `data` must be a pointer returned by [`Map::ringbuf_reserve`]
    /// that has not yet been submitted or discarded (the verifier
    /// enforces this for BPF callers via reference tracking).
    pub unsafe fn ringbuf_submit(data: *mut u8) {
        let hdr = &*(data.sub(RINGBUF_HDR_SIZE as usize) as *const AtomicU32);
        let len = hdr.load(Ordering::Relaxed) & RINGBUF_LEN_MASK;
        hdr.store(len, Ordering::Release);
    }

    /// Discard a reserved record: the consumer skips it (drop-accounted
    /// as discarded, not as a drop — the producer chose to abandon it).
    ///
    /// # Safety
    /// Same contract as [`Map::ringbuf_submit`].
    pub unsafe fn ringbuf_discard(data: *mut u8) {
        let hdr = &*(data.sub(RINGBUF_HDR_SIZE as usize) as *const AtomicU32);
        let len = hdr.load(Ordering::Relaxed) & RINGBUF_LEN_MASK;
        hdr.store(len | RINGBUF_DISCARD_BIT, Ordering::Release);
    }

    /// Copy `bytes` into the ring as one record (reserve + copy +
    /// submit). Returns 0 on success, -1 when the ring is full (the
    /// failed reservation is drop-accounted).
    pub fn ringbuf_output(&self, bytes: &[u8]) -> i64 {
        let p = self.ringbuf_reserve(bytes.len() as u64);
        if p.is_null() {
            return -1;
        }
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), p, bytes.len());
            Self::ringbuf_submit(p);
        }
        0
    }

    /// `bpf_ringbuf_query` (see [`ringbuf_query`] for flag values).
    pub fn ringbuf_query(&self, flag: u64) -> u64 {
        let Some(ring) = &self.ring else { return 0 };
        match flag {
            ringbuf_query::RING_SIZE => self.def.max_entries as u64,
            ringbuf_query::CONS_POS => ring.consumer.load(Ordering::Acquire),
            ringbuf_query::PROD_POS => ring.producer.load(Ordering::Acquire),
            _ => ring
                .producer
                .load(Ordering::Acquire)
                .saturating_sub(ring.consumer.load(Ordering::Acquire)),
        }
    }

    /// Producer-side drop count (failed reservations).
    pub fn ringbuf_dropped(&self) -> u64 {
        self.ring.as_ref().map(|r| r.drops.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Records the consumer skipped because the producer discarded
    /// them. Together with drains and drops this closes the accounting:
    /// `drained + dropped + discarded == reserve/output attempts`.
    pub fn ringbuf_discarded(&self) -> u64 {
        self.ring.as_ref().map(|r| r.discards.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Successfully reserved records (whether later submitted or
    /// discarded). Conservation against the consumer side:
    /// `emitted == drained + discarded + still-unconsumed records`.
    pub fn ringbuf_emitted(&self) -> u64 {
        self.ring.as_ref().map(|r| r.emitted.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Records delivered to drain callbacks over the map's lifetime
    /// (the producer-independent side of the conservation identity).
    pub fn ringbuf_drained(&self) -> u64 {
        self.ring.as_ref().map(|r| r.drained.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Deepest unconsumed backlog in bytes ever observed at reserve
    /// time — how close the ring has come to dropping.
    pub fn ringbuf_hiwater(&self) -> u64 {
        self.ring.as_ref().map(|r| r.hiwater.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Aggregate this map's operation-pressure counters (always on).
    pub fn pressure_stats(&self) -> MapPressureStats {
        self.pressure.aggregate()
    }

    /// Drain every completed record, invoking `cb` with each submitted
    /// payload (discarded records are skipped and counted in
    /// [`Map::ringbuf_discarded`]). Stops at the first still-BUSY
    /// record. Single-consumer: callers must serialize drains
    /// themselves (the host wraps this in
    /// [`crate::host::ringbuf::RingConsumer`]). Returns the number of
    /// records delivered to `cb`.
    pub fn ringbuf_drain(&self, cb: &mut dyn FnMut(&[u8])) -> usize {
        let Some(ring) = &self.ring else { return 0 };
        let mut delivered = 0usize;
        loop {
            let cons = ring.consumer.load(Ordering::Relaxed);
            let prod = ring.producer.load(Ordering::Acquire);
            if cons == prod {
                return delivered;
            }
            let hdr = ring.hdr(cons).load(Ordering::Acquire);
            if hdr & RINGBUF_BUSY_BIT != 0 {
                return delivered; // oldest record still being written
            }
            let len = (hdr & RINGBUF_LEN_MASK) as u64;
            if hdr & RINGBUF_DISCARD_BIT == 0 {
                let data = unsafe {
                    std::slice::from_raw_parts(
                        ring.byte_ptr(cons).add(RINGBUF_HDR_SIZE as usize),
                        len as usize,
                    )
                };
                cb(data);
                ring.drained.fetch_add(1, Ordering::Relaxed);
                delivered += 1;
            } else {
                ring.discards.fetch_add(1, Ordering::Relaxed);
            }
            ring.consumer.store(cons + RINGBUF_HDR_SIZE + round_up8(len), Ordering::Release);
        }
    }
}

// Per-thread logical cpu slot assignment. Slots are normally handed
// out round-robin on first map access; worker pools that need stable,
// collision-free slots (the traffic engine) pin them explicitly.
use std::sync::atomic::AtomicUsize;
static NEXT_CPU: AtomicUsize = AtomicUsize::new(0);
thread_local! {
    static CPU_SLOT: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}
fn thread_cpu_slot() -> usize {
    CPU_SLOT.with(|s| match s.get() {
        Some(v) => v,
        None => {
            let v = NEXT_CPU.fetch_add(1, Ordering::Relaxed) % NCPU;
            s.set(Some(v));
            v
        }
    })
}

/// Pin the calling thread's logical cpu slot (mod [`NCPU`]). Returns
/// the slot actually assigned. The traffic engine pins worker `i` to
/// slot `i` so per-cpu counters are single-writer and their all-slot
/// sum is exact.
pub fn pin_thread_cpu_slot(slot: usize) -> usize {
    let v = slot % NCPU;
    CPU_SLOT.with(|s| s.set(Some(v)));
    v
}

/// Shared namespace of maps: the mechanism behind cross-plugin
/// composability (§3, §5.3). Profiler and tuner programs loaded into the
/// same registry resolve `latency_map` to the same [`Map`] instance.
#[derive(Default)]
pub struct MapRegistry {
    inner: Mutex<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    by_id: StdHashMap<u32, Arc<Map>>,
    by_name: StdHashMap<String, u32>,
    next_id: u32,
}

impl MapRegistry {
    /// An empty registry (one per [`crate::host::NcclBpfHost`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a map, or return the existing one if a map with the same
    /// name and identical definition is already registered (this is what
    /// makes independently loaded profiler + tuner objects share state).
    pub fn create_or_get(&self, def: &MapDef) -> Result<Arc<Map>, String> {
        let mut g = self.inner.lock().unwrap();
        if let Some(&id) = g.by_name.get(&def.name) {
            let existing = g.by_id.get(&id).unwrap().clone();
            if existing.def != *def {
                return Err(format!(
                    "map '{}' already exists with a different definition \
                     (existing {:?}, requested {:?})",
                    def.name, existing.def, def
                ));
            }
            return Ok(existing);
        }
        g.next_id += 1;
        let id = g.next_id;
        let map = Arc::new(Map::new(def.clone(), id)?);
        g.by_id.insert(id, map.clone());
        g.by_name.insert(def.name.clone(), id);
        Ok(map)
    }

    /// Resolve a live map id (the `lddw map[id]` operand).
    pub fn by_id(&self, id: u32) -> Option<Arc<Map>> {
        self.inner.lock().unwrap().by_id.get(&id).cloned()
    }

    /// Resolve a map by its declared name.
    pub fn by_name(&self, name: &str) -> Option<Arc<Map>> {
        let g = self.inner.lock().unwrap();
        let id = g.by_name.get(name)?;
        g.by_id.get(id).cloned()
    }

    /// Every registered map name (unsorted).
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().by_name.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adef(name: &str, vsize: u32, n: u32) -> MapDef {
        MapDef {
            name: name.into(),
            kind: MapKind::Array,
            key_size: 4,
            value_size: vsize,
            max_entries: n,
        }
    }

    fn hdef(name: &str, ksize: u32, vsize: u32, n: u32) -> MapDef {
        MapDef {
            name: name.into(),
            kind: MapKind::Hash,
            key_size: ksize,
            value_size: vsize,
            max_entries: n,
        }
    }

    #[test]
    fn array_lookup_in_bounds() {
        let m = Map::new(adef("a", 8, 4), 1).unwrap();
        for i in 0..4u32 {
            assert!(!m.lookup(&i.to_le_bytes()).is_null());
        }
        assert!(m.lookup(&4u32.to_le_bytes()).is_null());
        assert!(m.lookup(&u32::MAX.to_le_bytes()).is_null());
    }

    #[test]
    fn array_update_read() {
        let m = Map::new(adef("a", 8, 4), 1).unwrap();
        m.write_u64(2, 0xfeed).unwrap();
        assert_eq!(m.read_u64(2), Some(0xfeed));
        assert_eq!(m.read_u64(0), Some(0)); // zero-initialized
        assert!(m.write_u64(9, 1).is_err());
    }

    #[test]
    fn array_lookup_pointer_is_stable_and_writable() {
        let m = Map::new(adef("a", 8, 2), 1).unwrap();
        let p1 = m.lookup(&1u32.to_le_bytes());
        unsafe { (p1 as *mut u64).write_unaligned(77) };
        let p2 = m.lookup(&1u32.to_le_bytes());
        assert_eq!(p1, p2);
        assert_eq!(m.read_u64(1), Some(77));
    }

    #[test]
    fn hash_insert_lookup_delete() {
        let m = Map::new(hdef("h", 4, 8, 8), 1).unwrap();
        assert!(m.lookup(&5u32.to_le_bytes()).is_null());
        m.write_u64(5, 500).unwrap();
        m.write_u64(13, 1300).unwrap(); // likely collides mod 8 with 5
        assert_eq!(m.read_u64(5), Some(500));
        assert_eq!(m.read_u64(13), Some(1300));
        assert_eq!(m.len(), 2);
        assert!(m.delete(&5u32.to_le_bytes()).unwrap());
        assert!(m.lookup(&5u32.to_le_bytes()).is_null());
        assert_eq!(m.read_u64(13), Some(1300)); // probe past tombstone
        assert_eq!(m.len(), 1);
        assert!(!m.delete(&5u32.to_le_bytes()).unwrap());
    }

    #[test]
    fn hash_overwrite_same_key() {
        let m = Map::new(hdef("h", 4, 8, 4), 1).unwrap();
        m.write_u64(1, 10).unwrap();
        m.write_u64(1, 20).unwrap();
        assert_eq!(m.read_u64(1), Some(20));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn hash_full() {
        let m = Map::new(hdef("h", 4, 8, 2), 1).unwrap();
        m.write_u64(1, 1).unwrap();
        m.write_u64(2, 2).unwrap();
        assert!(m.write_u64(3, 3).is_err());
        // deleting frees a slot (tombstone reuse)
        m.delete(&1u32.to_le_bytes()).unwrap();
        m.write_u64(3, 3).unwrap();
        assert_eq!(m.read_u64(3), Some(3));
    }

    #[test]
    fn pressure_counters_track_operations() {
        let m = Map::new(hdef("h", 4, 8, 4), 1).unwrap();
        m.write_u64(1, 10).unwrap(); // update
        let _ = m.read_u64(1); // lookup
        let _ = m.lookup(&9u32.to_le_bytes()); // miss still counts
        m.delete(&1u32.to_le_bytes()).unwrap(); // delete + tombstone left
        m.write_u64(1, 20).unwrap(); // update + tombstone reused
        let p = m.pressure_stats();
        assert_eq!(p.updates, 2);
        assert_eq!(p.deletes, 1);
        assert!(p.lookups >= 2);
        assert_eq!(p.tombstones, 2, "one left by delete, one reused by insert");
    }

    #[test]
    fn ringbuf_emitted_drained_hiwater_accounting() {
        let def = MapDef {
            name: "rb".into(),
            kind: MapKind::RingBuf,
            key_size: 0,
            value_size: 0,
            max_entries: 4096,
        };
        let m = Map::new(def, 1).unwrap();
        assert_eq!(m.ringbuf_emitted(), 0);
        for i in 0..3u64 {
            assert_eq!(m.ringbuf_output(&i.to_le_bytes()), 0);
        }
        // one reserved-then-discarded record
        let p = m.ringbuf_reserve(8);
        assert!(!p.is_null());
        unsafe { Map::ringbuf_discard(p) };
        assert_eq!(m.ringbuf_emitted(), 4);
        assert!(m.ringbuf_hiwater() >= 4 * 16, "4 records of 16 bytes backlogged");
        let mut n = 0usize;
        m.ringbuf_drain(&mut |_| n += 1);
        assert_eq!(n, 3);
        assert_eq!(m.ringbuf_drained(), 3);
        assert_eq!(m.ringbuf_discarded(), 1);
        // conservation: emitted == drained + discarded (+ 0 in flight)
        assert_eq!(m.ringbuf_emitted(), m.ringbuf_drained() + m.ringbuf_discarded());
    }

    #[test]
    fn hash_tombstone_reuse_keeps_probe_chain() {
        // force collisions: capacity 4, insert 3 keys hashing to a chain,
        // delete the middle, re-insert, ensure all reachable.
        let m = Map::new(hdef("h", 4, 8, 4), 1).unwrap();
        for k in [1u32, 2, 3] {
            m.write_u64(k, k as u64 * 100).unwrap();
        }
        m.delete(&2u32.to_le_bytes()).unwrap();
        m.write_u64(7, 700).unwrap();
        for (k, v) in [(1u32, 100u64), (3, 300), (7, 700)] {
            assert_eq!(m.read_u64(k), Some(v), "key {}", k);
        }
    }

    #[test]
    fn percpu_isolated_per_thread() {
        let def = MapDef {
            name: "pc".into(),
            kind: MapKind::PerCpuArray,
            key_size: 4,
            value_size: 8,
            max_entries: 2,
        };
        let m = Arc::new(Map::new(def, 1).unwrap());
        m.write_u64(0, 111).unwrap();
        let m2 = m.clone();
        let other = std::thread::spawn(move || {
            // a different thread gets its own slot (usually): its initial
            // value is 0 unless slots collide mod NCPU.
            let before = m2.read_u64(0).unwrap();
            m2.write_u64(0, 222).unwrap();
            before
        })
        .join()
        .unwrap();
        // this thread's value unchanged if slots differ
        if other == 0 {
            assert_eq!(m.read_u64(0), Some(111));
        }
    }

    fn pcdef(name: &str, entries: u32) -> MapDef {
        MapDef {
            name: name.into(),
            kind: MapKind::PerCpuArray,
            key_size: 4,
            value_size: 8,
            max_entries: entries,
        }
    }

    /// Regression for the control-plane per-cpu bug: a host `write_u64`
    /// only touched the calling thread's slot, so policies running on
    /// worker threads read 0. `write_u64_all` must be visible from
    /// every thread's slot.
    #[test]
    fn percpu_host_write_all_visible_cross_thread() {
        let m = Arc::new(Map::new(pcdef("pc_all", 2), 1).unwrap());
        // seed-style single-slot write: workers would read 0 (the bug)
        m.write_u64(0, 111).unwrap();
        // fixed control-plane path: every slot gets the value
        m.write_u64_all(1, 777).unwrap();
        let mut handles = vec![];
        for i in 0..4usize {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                pin_thread_cpu_slot(8 + i); // distinct slots, not the writer's
                m.read_u64(1).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 777, "worker thread must see the host write");
        }
        // error paths: out-of-range index, short value
        assert!(m.write_u64_all(9, 1).is_err());
    }

    /// Per-thread increments on pinned slots aggregate exactly through
    /// `read_u64_all` (single-writer slots, no lost updates).
    #[test]
    fn percpu_pinned_slots_aggregate_exactly() {
        let m = Arc::new(Map::new(pcdef("pc_sum", 1), 1).unwrap());
        let mut handles = vec![];
        for t in 0..4usize {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                let slot = pin_thread_cpu_slot(t);
                for _ in 0..1000 {
                    let cur = m.read_u64(0).unwrap();
                    m.write_u64(0, cur + 1).unwrap();
                }
                slot
            }));
        }
        let slots: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(slots, vec![0, 1, 2, 3]);
        assert_eq!(m.read_u64_all(0), Some(4000));
        for t in 0..4usize {
            assert_eq!(m.read_u64_cpu(0, t), Some(1000));
        }
    }

    #[test]
    fn validate_rejects_bad_defs() {
        assert!(Map::new(adef("a", 8, 0), 1).is_err());
        assert!(Map::new(
            MapDef { name: "x".into(), kind: MapKind::Array, key_size: 8, value_size: 8, max_entries: 1 },
            1
        )
        .is_err());
        assert!(Map::new(hdef("h", 0, 8, 1), 1).is_err());
    }

    #[test]
    fn registry_shares_by_name() {
        let r = MapRegistry::new();
        let a = r.create_or_get(&adef("latency_map", 16, 64)).unwrap();
        let b = r.create_or_get(&adef("latency_map", 16, 64)).unwrap();
        assert_eq!(a.id, b.id);
        a.write_u64(3, 42).unwrap();
        assert_eq!(b.read_u64(3), Some(42));
        assert!(r.create_or_get(&adef("latency_map", 8, 64)).is_err());
        assert!(r.by_name("latency_map").is_some());
        assert!(r.by_id(a.id).is_some());
        assert!(r.by_name("nope").is_none());
    }

    fn rbdef(name: &str, size: u32) -> MapDef {
        MapDef {
            name: name.into(),
            kind: MapKind::RingBuf,
            key_size: 0,
            value_size: 0,
            max_entries: size,
        }
    }

    #[test]
    fn ringbuf_reserve_submit_drain_roundtrip() {
        let m = Map::new(rbdef("rb", 4096), 1).unwrap();
        for i in 0..4u64 {
            let p = m.ringbuf_reserve(16);
            assert!(!p.is_null());
            unsafe {
                (p as *mut u64).write_unaligned(i);
                ((p as *mut u64).add(1)).write_unaligned(i * 10);
                Map::ringbuf_submit(p);
            }
        }
        let mut got = Vec::new();
        let n = m.ringbuf_drain(&mut |b| {
            assert_eq!(b.len(), 16);
            got.push(u64::from_le_bytes(b[..8].try_into().unwrap()));
        });
        assert_eq!(n, 4);
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(m.ringbuf_query(ringbuf_query::AVAIL_DATA), 0);
        assert_eq!(m.ringbuf_dropped(), 0);
    }

    #[test]
    fn ringbuf_discard_skipped_and_busy_blocks() {
        let m = Map::new(rbdef("rb", 4096), 1).unwrap();
        assert_eq!(m.ringbuf_output(&1u64.to_le_bytes()), 0);
        let d = m.ringbuf_reserve(8);
        unsafe { Map::ringbuf_discard(d) };
        m.ringbuf_output(&3u64.to_le_bytes());
        // a still-BUSY record blocks everything behind it
        let busy = m.ringbuf_reserve(8);
        m.ringbuf_output(&5u64.to_le_bytes());
        let mut got = Vec::new();
        m.ringbuf_drain(&mut |b| got.push(u64::from_le_bytes(b.try_into().unwrap())));
        assert_eq!(got, vec![1, 3], "discard skipped, BUSY blocks the tail");
        assert_eq!(m.ringbuf_discarded(), 1, "the skipped discard must be accounted");
        unsafe { Map::ringbuf_submit(busy) };
        m.ringbuf_drain(&mut |b| got.push(u64::from_le_bytes(b.try_into().unwrap())));
        assert_eq!(got, vec![1, 3, 0, 5]);
    }

    #[test]
    fn ringbuf_wraps_and_spills_across_boundary() {
        // 128-byte ring, 24-byte records (8 hdr + 16 data): positions
        // wrap repeatedly and some records spill into the slack region.
        let m = Map::new(rbdef("rb", 128), 1).unwrap();
        let mut next_payload = 0u64;
        let mut expect = 0u64;
        for _ in 0..200 {
            for _ in 0..3 {
                let mut rec = [0u8; 16];
                rec[..8].copy_from_slice(&next_payload.to_le_bytes());
                rec[8..].copy_from_slice(&(!next_payload).to_le_bytes());
                assert_eq!(m.ringbuf_output(&rec), 0);
                next_payload += 1;
            }
            m.ringbuf_drain(&mut |b| {
                assert_eq!(b.len(), 16);
                let lo = u64::from_le_bytes(b[..8].try_into().unwrap());
                let hi = u64::from_le_bytes(b[8..].try_into().unwrap());
                assert_eq!(lo, expect, "records must drain in order");
                assert_eq!(hi, !expect, "payload torn across the wrap boundary");
                expect += 1;
            });
        }
        assert_eq!(expect, next_payload);
        assert_eq!(m.ringbuf_dropped(), 0);
    }

    #[test]
    fn ringbuf_full_drops_and_recovers() {
        let m = Map::new(rbdef("rb", 64), 1).unwrap();
        // 24 bytes per record -> 2 fit in 64 bytes, the 3rd drops
        assert_eq!(m.ringbuf_output(&[1u8; 16]), 0);
        assert_eq!(m.ringbuf_output(&[2u8; 16]), 0);
        assert_eq!(m.ringbuf_output(&[3u8; 16]), -1);
        assert_eq!(m.ringbuf_dropped(), 1);
        // oversized reservation also drops
        assert!(m.ringbuf_reserve(64).is_null());
        assert_eq!(m.ringbuf_dropped(), 2);
        // draining frees space
        let mut n = 0;
        m.ringbuf_drain(&mut |_| n += 1);
        assert_eq!(n, 2);
        assert_eq!(m.ringbuf_output(&[4u8; 16]), 0);
    }

    #[test]
    fn ringbuf_query_flags() {
        let m = Map::new(rbdef("rb", 256), 1).unwrap();
        assert_eq!(m.ringbuf_query(ringbuf_query::RING_SIZE), 256);
        assert_eq!(m.ringbuf_output(&[0u8; 8]), 0);
        assert_eq!(m.ringbuf_query(ringbuf_query::AVAIL_DATA), 16);
        assert_eq!(m.ringbuf_query(ringbuf_query::PROD_POS), 16);
        assert_eq!(m.ringbuf_query(ringbuf_query::CONS_POS), 0);
        m.ringbuf_drain(&mut |_| {});
        assert_eq!(m.ringbuf_query(ringbuf_query::CONS_POS), 16);
        assert_eq!(m.len(), 0);
    }

    #[test]
    fn ringbuf_validate_rejects_bad_defs() {
        // non-power-of-two
        assert!(Map::new(rbdef("rb", 100), 1).is_err());
        // too small
        assert!(Map::new(rbdef("rb", 32), 1).is_err());
        // key/value sizes must be zero
        let mut d = rbdef("rb", 128);
        d.key_size = 4;
        assert!(Map::new(d, 1).is_err());
        // structured ops are rejected on ring maps
        let m = Map::new(rbdef("rb", 128), 1).unwrap();
        assert!(m.lookup(&[]).is_null());
        assert!(m.update(&[], &[]).is_err());
        assert!(m.delete(&[]).is_err());
    }

    /// MPSC integrity: 4 producers racing `ringbuf_output` against one
    /// consumer; every submitted record arrives exactly once, untorn
    /// (producer id and per-producer sequence agree in both halves),
    /// and per-producer sequences arrive in order.
    #[test]
    fn ringbuf_mpsc_concurrent_integrity() {
        const PRODUCERS: u64 = 4;
        const PER_PRODUCER: u64 = 5_000;
        let m = Arc::new(Map::new(rbdef("rb", 1 << 14), 1).unwrap());
        let done = Arc::new(AtomicU32::new(0));
        let mut handles = vec![];
        for p in 0..PRODUCERS {
            let m = m.clone();
            let done = done.clone();
            handles.push(std::thread::spawn(move || {
                let mut sent = 0u64;
                let mut seq = 0u64;
                while seq < PER_PRODUCER {
                    let tag = (p << 32) | seq;
                    let mut rec = [0u8; 16];
                    rec[..8].copy_from_slice(&tag.to_le_bytes());
                    rec[8..].copy_from_slice(&(!tag).to_le_bytes());
                    if m.ringbuf_output(&rec) == 0 {
                        sent += 1;
                    }
                    seq += 1;
                }
                done.fetch_add(1, Ordering::Release);
                sent
            }));
        }
        let consumer = {
            let m = m.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut next_seq = [0u64; PRODUCERS as usize];
                let mut received = 0u64;
                loop {
                    let drained = m.ringbuf_drain(&mut |b| {
                        let tag = u64::from_le_bytes(b[..8].try_into().unwrap());
                        let inv = u64::from_le_bytes(b[8..].try_into().unwrap());
                        assert_eq!(!tag, inv, "torn record");
                        let (p, seq) = ((tag >> 32) as usize, tag & 0xffff_ffff);
                        assert!(seq >= next_seq[p], "producer {} went backwards", p);
                        next_seq[p] = seq + 1;
                        received += 1;
                    });
                    if drained == 0 && done.load(Ordering::Acquire) == PRODUCERS as u32 {
                        // one final pass after all producers finished
                        m.ringbuf_drain(&mut |_| received += 1);
                        return received;
                    }
                    std::hint::spin_loop();
                }
            })
        };
        let sent: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let received = consumer.join().unwrap();
        assert_eq!(received, sent, "every submitted record must be drained exactly once");
        assert_eq!(sent + m.ringbuf_dropped(), PRODUCERS * PER_PRODUCER);
    }

    #[test]
    fn prog_array_slots_and_type_pinning() {
        let def = MapDef {
            name: "chain".into(),
            kind: MapKind::ProgArray,
            key_size: 4,
            value_size: 4,
            max_entries: 4,
        };
        let m = Map::new(def, 1).unwrap();
        assert!(m.is_empty());
        assert!(m.prog_array_get(0).is_none());
        assert!(m.prog_array_get(99).is_none(), "out of range is empty, not an error");
        let slot = |tag: u32, v: u64| ProgSlot { tag, handle: Arc::new(v) };
        m.prog_array_set(0, slot(0, 10)).unwrap();
        m.prog_array_set(2, slot(0, 12)).unwrap();
        assert_eq!(m.len(), 2);
        // type pinning: a differently-tagged program is rejected
        let err = m.prog_array_set(1, slot(1, 11)).unwrap_err();
        assert!(err.contains("incompatible"), "{}", err);
        // atomic replacement of one slot leaves the others untouched
        m.prog_array_set(0, slot(0, 99)).unwrap();
        let got = m.prog_array_get(0).unwrap();
        assert_eq!(*got.handle.downcast_ref::<u64>().unwrap(), 99);
        let other = m.prog_array_get(2).unwrap();
        assert_eq!(*other.handle.downcast_ref::<u64>().unwrap(), 12);
        // bounds + clear
        assert!(m.prog_array_set(4, slot(0, 1)).is_err());
        assert!(m.prog_array_clear(2));
        assert!(!m.prog_array_clear(2));
        assert_eq!(m.len(), 1);
        // prog arrays have no byte elements
        assert!(m.lookup(&0u32.to_le_bytes()).is_null());
        assert!(m.update(&0u32.to_le_bytes(), &0u32.to_le_bytes()).is_err());
        assert!(m.delete(&0u32.to_le_bytes()).is_err());
        // shape validation
        let bad = MapDef {
            name: "b".into(),
            kind: MapKind::ProgArray,
            key_size: 8,
            value_size: 4,
            max_entries: 4,
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn concurrent_hash_updates() {
        let m = Arc::new(Map::new(hdef("h", 4, 8, 256), 1).unwrap());
        let mut handles = vec![];
        for t in 0..4u32 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u32 {
                    m.write_u64(t * 100 + i, (t * 100 + i) as u64).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.len(), 200);
        for t in 0..4u32 {
            for i in 0..50u32 {
                assert_eq!(m.read_u64(t * 100 + i), Some((t * 100 + i) as u64));
            }
        }
    }
}
