//! Execution engine for *verified* programs.
//!
//! Instructions are pre-decoded at load time into a compact internal
//! form ([`Op`]) so the per-call hot path is a single match dispatch per
//! instruction with no bit-twiddling — this is the "JIT-narrowed" layer
//! whose dispatch cost Table 1 measures (the optional native x86-64 JIT
//! lives in [`super::jit`]).
//!
//! # Safety contract
//! The engine dereferences raw pointers (ctx, stack, map values) without
//! runtime checks, exactly like JIT-compiled eBPF: safety is established
//! *statically* by [`super::verifier`]. The only public way to construct
//! a runnable program is [`super::program::load`], which
//! verifies first.

use super::helpers::{id as hid, HelperEnv};
use super::insn::{alu, atomic, class, jmp, mode, pseudo, size, src, Insn};
use super::program::{resolve_tail_call, LoadedProgram};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering::SeqCst};
use std::sync::Arc;

/// Kernel chain limit: at most 33 taken tail calls per execution.
pub const MAX_TAIL_CALLS: u32 = 33;

thread_local! {
    /// Taken tail calls in the current top-level execution. Shared with
    /// the JIT's tail-call trampoline so a chain that crosses engines
    /// (a JIT'd link dispatching into an interpreted one) still counts
    /// as ONE chain against [`MAX_TAIL_CALLS`].
    pub static TAIL_DEPTH: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

/// Pre-decoded instruction. Register indices are u8; `t` is the jump
/// target (absolute pc) for branch ops.
#[derive(Clone, Copy, Debug)]
pub enum Op {
    /// 64-bit ALU, register source
    Alu64Reg { op: u8, dst: u8, src: u8 },
    /// 64-bit ALU, immediate source
    Alu64Imm { op: u8, dst: u8, imm: i64 },
    /// 32-bit ALU, register source (zero-extends)
    Alu32Reg { op: u8, dst: u8, src: u8 },
    /// 32-bit ALU, immediate source (zero-extends)
    Alu32Imm { op: u8, dst: u8, imm: i64 },
    /// 64-bit negate
    Neg64 { dst: u8 },
    /// 32-bit negate (zero-extends)
    Neg32 { dst: u8 },
    /// memory load `dst = *(width*)(src + off)`
    Load { width: u8, dst: u8, src: u8, off: i16 },
    /// memory store `*(width*)(dst + off) = src`
    Store { width: u8, dst: u8, src: u8, off: i16 },
    /// memory store `*(width*)(dst + off) = imm`
    StoreImm { width: u8, dst: u8, off: i16, imm: i64 },
    /// atomic read-modify-write on `*(width*)(dst + off)`; `aop` is the
    /// [`atomic`] selector from the instruction's `imm` field
    Atomic { aop: i32, dst: u8, src: u8, off: i16, is64: bool },
    /// 64-bit immediate load (from lddw)
    LoadImm64 { dst: u8, imm: u64 },
    /// resolved map reference: value is the map id (helpers resolve it)
    LoadMapFd { dst: u8, map_id: u32 },
    /// unconditional jump
    Ja { t: u32 },
    /// conditional jump, register source
    JmpReg { op: u8, dst: u8, src: u8, t: u32, is32: bool },
    /// conditional jump, immediate source
    JmpImm { op: u8, dst: u8, imm: i64, t: u32, is32: bool },
    /// helper call by id (tail calls are intercepted by the engines)
    Call { helper: i32 },
    /// bpf-to-bpf call to the subprogram starting at op index `t`
    CallPseudo { t: u32 },
    /// program / subprogram exit
    Exit,
}

/// Decode a verified instruction stream into the internal form.
/// `pc` values in branches are absolute indices into the *decoded* vec;
/// because `lddw` collapses 2 slots into 1 op, we first build a slot→op
/// index mapping.
pub fn predecode(insns: &[Insn]) -> Result<Vec<Op>, String> {
    predecode_mapped(insns).map(|(ops, _)| ops)
}

/// [`predecode`] that also returns the raw-slot → op-index mapping
/// (`u32::MAX` marks lddw interiors). The verifier's per-instruction
/// fact table is slot-indexed; the JIT consumes ops — this mapping is
/// how `remap_facts` translates between the two.
pub fn predecode_mapped(insns: &[Insn]) -> Result<(Vec<Op>, Vec<u32>), String> {
    // map raw slot index -> decoded index
    let mut slot2op = vec![u32::MAX; insns.len() + 1];
    let mut count = 0u32;
    let mut i = 0;
    while i < insns.len() {
        slot2op[i] = count;
        count += 1;
        i += if insns[i].is_lddw() { 2 } else { 1 };
    }
    slot2op[insns.len()] = count;

    let mut ops = Vec::with_capacity(count as usize);
    let mut i = 0;
    while i < insns.len() {
        let ins = insns[i];
        let cls = ins.class();
        let op = match cls {
            class::ALU64 | class::ALU => {
                let aop = ins.op();
                if aop == alu::NEG {
                    if cls == class::ALU64 {
                        Op::Neg64 { dst: ins.dst }
                    } else {
                        Op::Neg32 { dst: ins.dst }
                    }
                } else if ins.src_flag() == src::X {
                    if cls == class::ALU64 {
                        Op::Alu64Reg { op: aop, dst: ins.dst, src: ins.src }
                    } else {
                        Op::Alu32Reg { op: aop, dst: ins.dst, src: ins.src }
                    }
                } else if cls == class::ALU64 {
                    Op::Alu64Imm { op: aop, dst: ins.dst, imm: ins.imm as i64 }
                } else {
                    Op::Alu32Imm { op: aop, dst: ins.dst, imm: ins.imm as u32 as i64 }
                }
            }
            class::LDX => Op::Load {
                width: ins.sz(),
                dst: ins.dst,
                src: ins.src,
                off: ins.off,
            },
            class::STX => {
                if ins.mode() == mode::ATOMIC {
                    if ins.sz() != size::W && ins.sz() != size::DW {
                        return Err("atomic ops must be 32- or 64-bit".into());
                    }
                    match ins.imm {
                        atomic::XCHG | atomic::CMPXCHG => {}
                        x if matches!(
                            x & !atomic::FETCH,
                            atomic::ADD | atomic::OR | atomic::AND | atomic::XOR
                        ) => {}
                        other => return Err(format!("unknown atomic op {:#x}", other)),
                    }
                    Op::Atomic {
                        aop: ins.imm,
                        dst: ins.dst,
                        src: ins.src,
                        off: ins.off,
                        is64: ins.sz() == size::DW,
                    }
                } else {
                    Op::Store { width: ins.sz(), dst: ins.dst, src: ins.src, off: ins.off }
                }
            }
            class::ST => Op::StoreImm {
                width: ins.sz(),
                dst: ins.dst,
                off: ins.off,
                imm: ins.imm as i64,
            },
            class::LD => {
                if !ins.is_lddw() {
                    return Err(format!("unsupported LD opcode {:#x}", ins.opcode));
                }
                let hi = insns[i + 1].imm as u32 as u64;
                let v = (ins.imm as u32 as u64) | (hi << 32);
                let o = if ins.src == pseudo::MAP_FD {
                    Op::LoadMapFd { dst: ins.dst, map_id: ins.imm as u32 }
                } else {
                    Op::LoadImm64 { dst: ins.dst, imm: v }
                };
                ops.push(o);
                i += 2;
                continue;
            }
            class::JMP | class::JMP32 => {
                let jop = ins.op();
                if jop == jmp::EXIT {
                    Op::Exit
                } else if jop == jmp::CALL {
                    if ins.is_pseudo_call() {
                        let tgt_slot = i as i64 + 1 + ins.imm as i64;
                        if tgt_slot < 0 || tgt_slot as usize >= insns.len() {
                            return Err(format!("pseudo call target {} out of range", tgt_slot));
                        }
                        let t = slot2op[tgt_slot as usize];
                        if t == u32::MAX {
                            return Err(format!(
                                "pseudo call into lddw interior at slot {}",
                                tgt_slot
                            ));
                        }
                        Op::CallPseudo { t }
                    } else {
                        Op::Call { helper: ins.imm }
                    }
                } else {
                    let tgt_slot = (i as i64 + 1 + ins.off as i64) as usize;
                    let t = slot2op[tgt_slot];
                    if t == u32::MAX {
                        return Err(format!("branch into lddw interior at slot {}", tgt_slot));
                    }
                    if jop == jmp::JA {
                        Op::Ja { t }
                    } else if ins.src_flag() == src::X {
                        Op::JmpReg {
                            op: jop,
                            dst: ins.dst,
                            src: ins.src,
                            t,
                            is32: cls == class::JMP32,
                        }
                    } else {
                        let imm = if cls == class::JMP32 {
                            ins.imm as u32 as i64
                        } else {
                            ins.imm as i64
                        };
                        Op::JmpImm { op: jop, dst: ins.dst, imm, t, is32: cls == class::JMP32 }
                    }
                }
            }
            c => return Err(format!("unknown class {:#x}", c)),
        };
        ops.push(op);
        i += 1;
    }
    Ok((ops, slot2op))
}

/// Translate the verifier's slot-indexed [`InsnFacts`] table into an
/// op-indexed one for the JIT, using the `slot2op` mapping from
/// [`predecode_mapped`]. lddw interiors (`u32::MAX`) carry no facts.
/// Returns an empty vec when `facts` is empty (fact emission was off) —
/// the JIT treats that as "no facts, trampoline everything".
pub fn remap_facts(
    facts: &[super::verifier::InsnFacts],
    slot2op: &[u32],
    n_ops: usize,
) -> Vec<super::verifier::InsnFacts> {
    if facts.is_empty() {
        return Vec::new();
    }
    let mut out = vec![super::verifier::InsnFacts::default(); n_ops];
    for (slot, f) in facts.iter().enumerate() {
        let op = slot2op.get(slot).copied().unwrap_or(u32::MAX);
        if op != u32::MAX && (op as usize) < n_ops {
            out[op as usize] = *f;
        }
    }
    out
}

#[inline(always)]
fn alu64(op: u8, a: u64, b: u64) -> u64 {
    match op {
        alu::ADD => a.wrapping_add(b),
        alu::SUB => a.wrapping_sub(b),
        alu::MUL => a.wrapping_mul(b),
        alu::DIV => {
            if b == 0 {
                0
            } else {
                a / b
            }
        }
        alu::MOD => {
            if b == 0 {
                a
            } else {
                a % b
            }
        }
        alu::OR => a | b,
        alu::AND => a & b,
        alu::LSH => a.wrapping_shl(b as u32),
        alu::RSH => a.wrapping_shr(b as u32),
        alu::XOR => a ^ b,
        alu::MOV => b,
        alu::ARSH => ((a as i64) >> (b & 63)) as u64,
        alu::END => a, // little-endian host: to_le is identity
        _ => a,
    }
}

/// 32-bit ALU semantics (BPF: shift counts mask at 31, ARSH
/// sign-extends from bit 31 — matching the x86 JIT exactly).
#[inline(always)]
fn alu32(op: u8, a: u32, b: u32) -> u32 {
    match op {
        alu::ADD => a.wrapping_add(b),
        alu::SUB => a.wrapping_sub(b),
        alu::MUL => a.wrapping_mul(b),
        alu::DIV => {
            if b == 0 {
                0
            } else {
                a / b
            }
        }
        alu::MOD => {
            if b == 0 {
                a
            } else {
                a % b
            }
        }
        alu::OR => a | b,
        alu::AND => a & b,
        alu::LSH => a.wrapping_shl(b),
        alu::RSH => a.wrapping_shr(b),
        alu::XOR => a ^ b,
        alu::MOV => b,
        alu::ARSH => ((a as i32) >> (b & 31)) as u32,
        alu::END => a,
        _ => a,
    }
}

#[inline(always)]
fn jmp_taken(op: u8, a: u64, b: u64, is32: bool) -> bool {
    let (a, b) = if is32 { (a as u32 as u64, b as u32 as u64) } else { (a, b) };
    let (sa, sb) = if is32 {
        (a as u32 as i32 as i64, b as u32 as i32 as i64)
    } else {
        (a as i64, b as i64)
    };
    match op {
        jmp::JEQ => a == b,
        jmp::JNE => a != b,
        jmp::JGT => a > b,
        jmp::JGE => a >= b,
        jmp::JLT => a < b,
        jmp::JLE => a <= b,
        jmp::JSET => a & b != 0,
        jmp::JSGT => sa > sb,
        jmp::JSGE => sa >= sb,
        jmp::JSLT => sa < sb,
        jmp::JSLE => sa <= sb,
        _ => false,
    }
}

/// One runtime bpf-to-bpf frame: the caller's resume point plus the
/// machine-preserved registers (BPF r6–r9 and the frame pointer r10).
struct CallFrame {
    ret: usize,
    saved: [u64; 5],
}

/// Execute a pre-decoded, verified program.
///
/// `ctx` is the policy context pointer handed to the program in R1.
/// Returns R0.
///
/// bpf-to-bpf calls push a runtime frame and give the callee a fresh
/// 512-byte stack region (the verifier's cumulative cap bounds what a
/// verified chain can actually touch); `bpf_tail_call` replaces the
/// executing program in place — same frame, r1 still the ctx — so an
/// interpreted chain runs entirely inside this one loop.
///
/// # Safety
/// `ops` must come from a program accepted by the verifier with a ctx
/// layout matching what `ctx` points to, and `env` must contain every
/// map id the program references.
pub unsafe fn execute(ops: &[Op], ctx: *mut u8, env: &HelperEnv) -> u64 {
    let mut regs = [0u64; 11];
    // 512-byte stack, 16-aligned.
    let mut stack = Stack512::new();
    regs[1] = ctx as u64;
    regs[10] = stack.top();

    let mut frames: Vec<CallFrame> = Vec::new();
    // boxed so pushing never moves a live frame's storage out from
    // under its r10; popped with the frame (callee stacks are dead on
    // return — verified code cannot read them again)
    let mut frame_stacks: Vec<Box<Stack512>> = Vec::new();

    // tail calls swap the executing program; the Arcs keep every
    // chained program alive until this call returns. Raw pointers keep
    // the borrow checker out of the (safe-by-Arc) self-reference.
    let mut cur_ops: *const [Op] = ops;
    let mut cur_env: *const HelperEnv = env;
    let mut held: Vec<Arc<LoadedProgram>> = Vec::new();
    let depth0 = TAIL_DEPTH.with(|d| d.get());

    let mut pc = 0usize;
    loop {
        debug_assert!(pc < (*cur_ops).len());
        match *(*cur_ops).get_unchecked(pc) {
            Op::Alu64Reg { op, dst, src } => {
                regs[dst as usize] = alu64(op, regs[dst as usize], regs[src as usize]);
                pc += 1;
            }
            Op::Alu64Imm { op, dst, imm } => {
                regs[dst as usize] = alu64(op, regs[dst as usize], imm as u64);
                pc += 1;
            }
            Op::Alu32Reg { op, dst, src } => {
                regs[dst as usize] =
                    alu32(op, regs[dst as usize] as u32, regs[src as usize] as u32) as u64;
                pc += 1;
            }
            Op::Alu32Imm { op, dst, imm } => {
                regs[dst as usize] =
                    alu32(op, regs[dst as usize] as u32, imm as u32) as u64;
                pc += 1;
            }
            Op::Neg64 { dst } => {
                regs[dst as usize] = (regs[dst as usize] as i64).wrapping_neg() as u64;
                pc += 1;
            }
            Op::Neg32 { dst } => {
                regs[dst as usize] = (regs[dst as usize] as u32 as i32).wrapping_neg() as u32 as u64;
                pc += 1;
            }
            Op::Load { width, dst, src, off } => {
                let p = (regs[src as usize] as *const u8).offset(off as isize);
                regs[dst as usize] = match width {
                    size::B => p.read_unaligned() as u64,
                    size::H => (p as *const u16).read_unaligned() as u64,
                    size::W => (p as *const u32).read_unaligned() as u64,
                    _ => (p as *const u64).read_unaligned(),
                };
                pc += 1;
            }
            Op::Store { width, dst, src, off } => {
                let p = (regs[dst as usize] as *mut u8).offset(off as isize);
                let v = regs[src as usize];
                match width {
                    size::B => p.write_unaligned(v as u8),
                    size::H => (p as *mut u16).write_unaligned(v as u16),
                    size::W => (p as *mut u32).write_unaligned(v as u32),
                    _ => (p as *mut u64).write_unaligned(v),
                }
                pc += 1;
            }
            Op::StoreImm { width, dst, off, imm } => {
                let p = (regs[dst as usize] as *mut u8).offset(off as isize);
                match width {
                    size::B => p.write_unaligned(imm as u8),
                    size::H => (p as *mut u16).write_unaligned(imm as u16),
                    size::W => (p as *mut u32).write_unaligned(imm as u32),
                    _ => (p as *mut u64).write_unaligned(imm as u64),
                }
                pc += 1;
            }
            Op::Atomic { aop, dst, src, off, is64 } => {
                // The verifier only admits atomics on map-value memory
                // with discharged bounds and natural alignment, and map
                // value storage is 8-aligned — so the AtomicU32/U64
                // overlays below are well-formed references.
                let p = (regs[dst as usize] as *mut u8).offset(off as isize);
                let v = regs[src as usize];
                if is64 {
                    let a = &*(p as *const AtomicU64);
                    match aop {
                        atomic::XCHG => regs[src as usize] = a.swap(v, SeqCst),
                        atomic::CMPXCHG => {
                            regs[0] = match a.compare_exchange(regs[0], v, SeqCst, SeqCst) {
                                Ok(old) | Err(old) => old,
                            };
                        }
                        _ => {
                            let old = match aop & !atomic::FETCH {
                                atomic::OR => a.fetch_or(v, SeqCst),
                                atomic::AND => a.fetch_and(v, SeqCst),
                                atomic::XOR => a.fetch_xor(v, SeqCst),
                                _ => a.fetch_add(v, SeqCst),
                            };
                            if aop & atomic::FETCH != 0 {
                                regs[src as usize] = old;
                            }
                        }
                    }
                } else {
                    let a = &*(p as *const AtomicU32);
                    let v = v as u32;
                    match aop {
                        atomic::XCHG => regs[src as usize] = a.swap(v, SeqCst) as u64,
                        atomic::CMPXCHG => {
                            // 32-bit cmpxchg compares against the low
                            // half of r0 and zero-extends the old value
                            // into r0, matching x86 `lock cmpxchg`.
                            regs[0] = match a.compare_exchange(
                                regs[0] as u32,
                                v,
                                SeqCst,
                                SeqCst,
                            ) {
                                Ok(old) | Err(old) => old as u64,
                            };
                        }
                        _ => {
                            let old = match aop & !atomic::FETCH {
                                atomic::OR => a.fetch_or(v, SeqCst),
                                atomic::AND => a.fetch_and(v, SeqCst),
                                atomic::XOR => a.fetch_xor(v, SeqCst),
                                _ => a.fetch_add(v, SeqCst),
                            };
                            if aop & atomic::FETCH != 0 {
                                regs[src as usize] = old as u64;
                            }
                        }
                    }
                }
                pc += 1;
            }
            Op::LoadImm64 { dst, imm } => {
                regs[dst as usize] = imm;
                pc += 1;
            }
            Op::LoadMapFd { dst, map_id } => {
                // maps are addressed by id through the helper env
                regs[dst as usize] = map_id as u64;
                pc += 1;
            }
            Op::Ja { t } => pc = t as usize,
            Op::JmpReg { op, dst, src, t, is32 } => {
                pc = if jmp_taken(op, regs[dst as usize], regs[src as usize], is32) {
                    t as usize
                } else {
                    pc + 1
                };
            }
            Op::JmpImm { op, dst, imm, t, is32 } => {
                pc = if jmp_taken(op, regs[dst as usize], imm as u64, is32) {
                    t as usize
                } else {
                    pc + 1
                };
            }
            Op::Call { helper } if helper == hid::TAIL_CALL => {
                // bpf_tail_call(ctx = r1, prog_array = r2, index = r3):
                // on success the current program is replaced in place
                // and the caller never resumes; on failure (empty slot,
                // out of range, chain limit, type mismatch) execution
                // falls through with a nonzero r0 — never a trap.
                let depth = TAIL_DEPTH.with(|d| d.get());
                let target = if depth >= MAX_TAIL_CALLS {
                    None
                } else {
                    resolve_tail_call(&*cur_env, regs[2] as u32, regs[3])
                };
                match target {
                    Some(t) => {
                        TAIL_DEPTH.with(|d| d.set(depth + 1));
                        debug_assert!(frames.is_empty(), "tail call from frame 0 only");
                        // kernel-style attribution: the dispatch counts
                        // against the initiator; the target gets no
                        // run_cnt of its own (no re-entry)
                        if let Some(cell) = &(*cur_env).stats {
                            cell.record_tail_call(depth + 1);
                        }
                        // same-frame semantics: r10 keeps the current
                        // stack; r1 already holds the ctx argument
                        cur_ops = t.ops.as_slice();
                        cur_env = &t.env;
                        held.push(t);
                        pc = 0;
                    }
                    None => {
                        if let Some(cell) = &(*cur_env).stats {
                            cell.record_error();
                        }
                        regs[0] = u64::MAX;
                        pc += 1;
                    }
                }
            }
            Op::Call { helper } => {
                let args = [regs[1], regs[2], regs[3], regs[4], regs[5]];
                regs[0] = (*cur_env).call(helper, args);
                pc += 1;
            }
            Op::CallPseudo { t } => {
                frames.push(CallFrame {
                    ret: pc + 1,
                    saved: [regs[6], regs[7], regs[8], regs[9], regs[10]],
                });
                let mut s = Box::new(Stack512::new());
                regs[10] = s.top();
                frame_stacks.push(s);
                pc = t as usize;
            }
            Op::Exit => match frames.pop() {
                Some(f) => {
                    regs[6] = f.saved[0];
                    regs[7] = f.saved[1];
                    regs[8] = f.saved[2];
                    regs[9] = f.saved[3];
                    regs[10] = f.saved[4];
                    frame_stacks.pop();
                    pc = f.ret;
                }
                None => {
                    TAIL_DEPTH.with(|d| d.set(depth0));
                    return regs[0];
                }
            },
        }
    }
}

/// 512-byte, 16-aligned program stack.
///
/// Deliberately *not* zeroed per call: the verifier enforces
/// init-before-read on every stack byte, so a verified program can
/// never observe the uninitialized contents, and zeroing 512 B on
/// every invocation would dominate the ns-scale dispatch cost Table 1
/// measures (the `interp_stack_zeroed` bench series documents the
/// delta). `MaybeUninit` makes that honest — the seed's
/// `Stack512([0u8; 512])` claimed "not zeroed" in a comment while
/// memsetting the whole array on every interpreter call.
#[repr(align(16))]
pub struct Stack512(std::mem::MaybeUninit<[u8; 512]>);
impl Stack512 {
    /// A fresh (deliberately uninitialized) stack region.
    #[inline(always)]
    pub fn new() -> Self {
        Stack512(std::mem::MaybeUninit::uninit())
    }
    /// One-past-the-end address — the value BPF r10 starts at.
    #[inline(always)]
    pub fn top(&mut self) -> u64 {
        unsafe { (self.0.as_mut_ptr() as *mut u8).add(512) as u64 }
    }
}

impl Default for Stack512 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpf::insn::*;
    use crate::bpf::maps::{MapDef, MapKind, MapRegistry};

    fn env() -> HelperEnv {
        HelperEnv { maps: vec![], printk: None, prog_type: None, stats: None }
    }

    unsafe fn run(prog: &[Insn]) -> u64 {
        let ops = predecode(prog).unwrap();
        execute(&ops, std::ptr::null_mut(), &env())
    }

    #[test]
    fn arithmetic() {
        unsafe {
            assert_eq!(run(&[mov64_imm(0, 2), alu64_imm(alu::ADD, 0, 40), exit()]), 42);
            assert_eq!(run(&[mov64_imm(0, 7), alu64_imm(alu::MUL, 0, 6), exit()]), 42);
            assert_eq!(run(&[mov64_imm(0, 85), alu64_imm(alu::DIV, 0, 2), exit()]), 42);
            assert_eq!(run(&[mov64_imm(0, -1), exit()]), u64::MAX);
            // 32-bit ops zero-extend
            assert_eq!(run(&[mov64_imm(0, -1), alu32_imm(alu::ADD, 0, 1), exit()]), 0);
        }
    }

    #[test]
    fn runtime_div_mod_zero_yield_defined_results() {
        // the verifier normally rejects these; the engine still defines
        // div/0 = 0 and mod/0 = dividend (kernel semantics) for defense
        // in depth.
        unsafe {
            assert_eq!(
                run(&[mov64_imm(0, 10), mov64_imm(1, 0), alu64_reg(alu::DIV, 0, 1), exit()]),
                0
            );
            assert_eq!(
                run(&[mov64_imm(0, 10), mov64_imm(1, 0), alu64_reg(alu::MOD, 0, 1), exit()]),
                10
            );
        }
    }

    #[test]
    fn branches_and_loop() {
        // sum 0..10 = 45
        let prog = [
            mov64_imm(0, 0),
            mov64_imm(2, 0),
            jmp_imm(jmp::JGE, 2, 10, 3),
            alu64_reg(alu::ADD, 0, 2),
            alu64_imm(alu::ADD, 2, 1),
            ja(-4),
            exit(),
        ];
        unsafe { assert_eq!(run(&prog), 45) };
    }

    #[test]
    fn signed_compare() {
        // r1 = -5; if r1 s< 0 then r0 = 1 else r0 = 0
        let prog = [
            mov64_imm(1, -5),
            mov64_imm(0, 0),
            jmp_imm(jmp::JSLT, 1, 0, 1),
            exit(),
            mov64_imm(0, 1),
            exit(),
        ];
        unsafe { assert_eq!(run(&prog), 1) };
    }

    #[test]
    fn lddw_and_stack() {
        let mut p = vec![];
        p.extend(lddw(1, 0, 0x1122_3344_5566_7788));
        p.push(stx(size::DW, 10, 1, -8));
        p.push(ldx(size::W, 0, 10, -8)); // low 32 bits
        p.push(exit());
        unsafe { assert_eq!(run(&p), 0x5566_7788) };
    }

    #[test]
    fn ctx_access() {
        let mut ctx = [0u8; 16];
        ctx[0..8].copy_from_slice(&123u64.to_le_bytes());
        let prog = [ldx(size::DW, 0, 1, 0), alu64_imm(alu::ADD, 0, 1), exit()];
        let ops = predecode(&prog).unwrap();
        let r = unsafe { execute(&ops, ctx.as_mut_ptr(), &env()) };
        assert_eq!(r, 124);
        // write back through ctx
        let prog2 = [st_imm(size::W, 1, 8, 77), mov64_imm(0, 0), exit()];
        let ops2 = predecode(&prog2).unwrap();
        unsafe { execute(&ops2, ctx.as_mut_ptr(), &env()) };
        assert_eq!(u32::from_le_bytes(ctx[8..12].try_into().unwrap()), 77);
    }

    #[test]
    fn map_lookup_roundtrip() {
        let reg = MapRegistry::new();
        let m = reg
            .create_or_get(&MapDef {
                name: "m".into(),
                kind: MapKind::Array,
                key_size: 4,
                value_size: 8,
                max_entries: 4,
            })
            .unwrap();
        m.write_u64(0, 555).unwrap();
        let env = HelperEnv::new(&reg, &[m.id]).unwrap();

        // key=0 on stack; lookup; null check; load value
        let mut p = vec![];
        p.extend(ld_map_fd(1, m.id));
        p.push(st_imm(size::W, 10, -4, 0));
        p.push(mov64_reg(2, 10));
        p.push(alu64_imm(alu::ADD, 2, -4));
        p.push(call(1));
        p.push(jmp_imm(jmp::JNE, 0, 0, 2));
        p.push(mov64_imm(0, 0));
        p.push(exit());
        p.push(ldx(size::DW, 0, 0, 0));
        p.push(exit());
        let ops = predecode(&p).unwrap();
        let r = unsafe { execute(&ops, std::ptr::null_mut(), &env) };
        assert_eq!(r, 555);
    }

    /// Regression for the stack-zeroing fix: the stack type must keep
    /// its ABI shape (512 bytes, 16-aligned, `top()` one-past-the-end)
    /// and stay readable/writable through the frame pointer — without
    /// the per-call memset the seed's `[0u8; 512]` initializer hid.
    #[test]
    fn stack512_layout_and_frame_pointer_access() {
        assert_eq!(std::mem::size_of::<Stack512>(), 512);
        assert_eq!(std::mem::align_of::<Stack512>(), 16);
        let mut s = Stack512::new();
        let top = s.top();
        assert_eq!(top % 16, 0, "stack top must stay 16-aligned");
        unsafe {
            ((top - 8) as *mut u64).write_unaligned(0xdead_beef);
            assert_eq!(((top - 8) as *const u64).read_unaligned(), 0xdead_beef);
            ((top - 512) as *mut u8).write(0x7f); // lowest addressable byte
            assert_eq!(((top - 512) as *const u8).read(), 0x7f);
        }
        // a program writing then reading its whole stack stays correct
        let mut p = vec![mov64_imm(0, 0)];
        for off in (8..=512i16).step_by(8) {
            p.push(st_imm(size::DW, 10, -off, off as i32));
        }
        for off in (8..=512i16).step_by(8) {
            p.push(ldx(size::DW, 1, 10, -off));
            p.push(alu64_reg(alu::ADD, 0, 1));
        }
        p.push(exit());
        let want: u64 = (8..=512u64).step_by(8).sum();
        unsafe { assert_eq!(run(&p), want) };
    }

    #[test]
    fn atomic_rmw_semantics() {
        use crate::bpf::insn::atomic;
        // engine-level test on an 8-aligned buffer handed in as ctx
        // (the verifier layer separately confines atomics to map values)
        let mut mem = [10u64, 0u64];
        let run_at = |prog: &[Insn], mem: &mut [u64; 2]| {
            let ops = predecode(prog).unwrap();
            unsafe { execute(&ops, mem.as_mut_ptr() as *mut u8, &env()) }
        };
        // fetch_add: r2 gets the old value, memory gets the sum
        let r = run_at(
            &[
                mov64_imm(2, 5),
                atomic_insn(size::DW, 1, 2, 0, atomic::ADD | atomic::FETCH),
                mov64_reg(0, 2),
                exit(),
            ],
            &mut mem,
        );
        assert_eq!(r, 10);
        assert_eq!(mem[0], 15);
        // fetchless add leaves the source register alone
        let r = run_at(
            &[
                mov64_imm(2, 7),
                atomic_insn(size::DW, 1, 2, 0, atomic::ADD),
                mov64_reg(0, 2),
                exit(),
            ],
            &mut mem,
        );
        assert_eq!(r, 7);
        assert_eq!(mem[0], 22);
        // xchg swaps
        let r = run_at(
            &[
                mov64_imm(2, 100),
                atomic_insn(size::DW, 1, 2, 0, atomic::XCHG),
                mov64_reg(0, 2),
                exit(),
            ],
            &mut mem,
        );
        assert_eq!(r, 22);
        assert_eq!(mem[0], 100);
        // cmpxchg success: r0 == memory, store happens, r0 = old
        let r = run_at(
            &[
                mov64_imm(0, 100),
                mov64_imm(2, 333),
                atomic_insn(size::DW, 1, 2, 0, atomic::CMPXCHG),
                exit(),
            ],
            &mut mem,
        );
        assert_eq!(r, 100);
        assert_eq!(mem[0], 333);
        // cmpxchg failure: r0 != memory, no store, r0 = observed value
        let r = run_at(
            &[
                mov64_imm(0, 1),
                mov64_imm(2, 444),
                atomic_insn(size::DW, 1, 2, 0, atomic::CMPXCHG),
                exit(),
            ],
            &mut mem,
        );
        assert_eq!(r, 333);
        assert_eq!(mem[0], 333);
    }

    #[test]
    fn atomic_32bit_zero_extends() {
        use crate::bpf::insn::atomic;
        let mut mem = [0u64, 0u64];
        mem[0] = 0xffff_ffff; // low word all-ones
        let prog = [
            mov64_imm(2, 1),
            atomic_insn(size::W, 1, 2, 0, atomic::ADD | atomic::FETCH),
            mov64_reg(0, 2),
            exit(),
        ];
        let ops = predecode(&prog).unwrap();
        let r = unsafe { execute(&ops, mem.as_mut_ptr() as *mut u8, &env()) };
        // old 32-bit value zero-extends into r2; low word wrapped to 0
        assert_eq!(r, 0xffff_ffff);
        assert_eq!(mem[0], 0);
        // 32-bit and/or/xor operate on the addressed word only
        let mut mem2 = [0x00ff_00ff_00ff_00ffu64, 0];
        let prog2 = [
            mov64_imm(2, 0x0f0f),
            atomic_insn(size::W, 1, 2, 4, atomic::AND),
            mov64_imm(0, 0),
            exit(),
        ];
        let ops2 = predecode(&prog2).unwrap();
        unsafe { execute(&ops2, mem2.as_mut_ptr() as *mut u8, &env()) };
        assert_eq!(mem2[0], 0x000f_000f_00ff_00ff);
    }

    #[test]
    fn predecode_rejects_bad_atomics() {
        // sub-width atomic
        let bad = Insn::new(crate::bpf::insn::class::STX | size::B | mode::ATOMIC, 1, 2, 0, 0);
        assert!(predecode(&[bad, exit()]).is_err());
        // unknown sub-op (0x10 = ALU SUB, which has no atomic form)
        let bad2 = atomic_insn(size::DW, 1, 2, 0, 0x10);
        assert!(predecode(&[bad2, exit()]).is_err());
    }

    #[test]
    fn subprog_call_frames_and_preserved_regs() {
        // main: r6..r9 live across the call; callee clobbers them all
        // and uses its own stack — the frame must restore the caller's
        let prog = [
            mov64_imm(6, 6),               // 0
            mov64_imm(7, 7),               // 1
            mov64_imm(8, 8),               // 2
            mov64_imm(9, 9),               // 3
            st_imm(size::DW, 10, -8, 50),  // 4: caller stack
            mov64_imm(1, 2),               // 5
            call_pseudo(5),                // 6 -> 12
            ldx(size::DW, 2, 10, -8),      // 7: caller stack intact
            alu64_reg(alu::ADD, 0, 2),     // 8
            alu64_reg(alu::ADD, 0, 6),     // 9
            alu64_reg(alu::ADD, 0, 7),     // 10
            exit(),                        // 11
            mov64_imm(6, 1000),            // 12: callee trashes r6-r9
            mov64_imm(7, 1000),            // 13
            mov64_imm(8, 1000),            // 14
            mov64_imm(9, 1000),            // 15
            st_imm(size::DW, 10, -8, 999), // 16: callee's own frame
            mov64_reg(0, 1),               // 17: r0 = arg
            exit(),                        // 18
        ];
        // r0 = 2 (callee) + 50 (caller stack) + 6 + 7 = 65
        unsafe { assert_eq!(run(&prog), 65) };
    }

    #[test]
    fn predecode_pseudo_call_rejects_bad_targets() {
        let bad = [mov64_imm(0, 0), call_pseudo(100), exit()];
        assert!(predecode(&bad).is_err());
        let mut into_lddw = vec![mov64_imm(0, 0), call_pseudo(1)];
        into_lddw.extend(lddw(1, 0, 7)); // target = slot 3 = lddw interior
        into_lddw.push(exit());
        assert!(predecode(&into_lddw).is_err());
    }

    #[test]
    fn predecode_jump_targets_account_for_lddw() {
        // jump over an lddw: targets must be remapped to op indices
        let mut p = vec![];
        p.push(jmp_imm(jmp::JEQ, 1, 0, 2)); // skip the lddw (2 slots)
        p.extend(lddw(0, 0, 7));
        p.push(exit()); // taken path lands here with r0 unset? set below
        // rewrite: make both paths defined
        let mut p2 = vec![mov64_imm(0, 1)];
        p2.extend(p);
        let ops = predecode(&p2).unwrap();
        // ops: mov, jeq(t), lddw(1 op), exit => 4 ops
        assert_eq!(ops.len(), 4);
        let r = unsafe { execute(&ops, std::ptr::null_mut(), &env()) };
        // r1=0 (zeroed regs) -> branch taken -> skips lddw, r0 stays 1
        assert_eq!(r, 1);
    }
}
