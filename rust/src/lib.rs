//! # NCCLbpf — Verified, Composable Policy Execution for GPU Collective Communication
//!
//! Reproduction of the NCCLbpf paper (CS.DC 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: a userspace
//!   eBPF runtime ([`bpf`]) embedded into the plugin interfaces of an
//!   NCCL-like collective communication engine ([`cc`]) via the plugin
//!   host ([`host`]), with load-time verification, typed cross-plugin
//!   maps, and atomic policy hot-reload.
//! - **Layer 2 (python/compile/model.py)** — a JAX transformer training
//!   step, AOT-lowered to HLO text and executed from Rust via PJRT
//!   ([`runtime`]); the distributed-training driver lives in [`train`].
//! - **Layer 1 (python/compile/kernels/)** — Pallas kernels (chunk
//!   reduction, LL-protocol pack/unpack, fused Adam) lowered into the
//!   same HLO artifacts.
//!
//! The original paper evaluates on 8x NVIDIA B300 GPUs with real NCCL
//! and bpftime. Neither GPUs nor NCCL are available here, so every
//! substrate is built from scratch: the eBPF ISA/verifier/JIT/maps, a
//! restricted-C policy compiler ([`bpfc`]), and a collective engine
//! with Ring/Tree/NVLS algorithms, LL/LL128/Simple protocols and an
//! NVLink performance model. See DESIGN.md for the substitution map.

// The substrate code favors explicitness over clippy's stylistic
// defaults in a few recurring shapes (state tuples in the assembler,
// the verifier's wide helper signatures, index-parallel kernel loops).
#![allow(
    clippy::type_complexity,
    clippy::too_many_arguments,
    clippy::needless_range_loop
)]

pub mod bench;
pub mod bpf;
pub mod bpfc;
pub mod cc;
pub mod cli;
pub mod docs;
pub mod host;
pub mod metrics;
pub mod runtime;
pub mod train;
pub mod util;
