//! Artifact runtime: load the AOT artifacts (HLO text) produced by
//! `python/compile/aot.py` and execute the kernel-shaped ones from the
//! Rust hot path. Python never runs at request time — the binary is
//! self-contained once `python -m compile.aot` has produced
//! `artifacts/`.
//!
//! ## Executor substitution (see DESIGN.md §PJRT)
//!
//! The original seed executed every artifact through the `xla` PJRT
//! bindings. That crate (and its bundled XLA runtime) cannot be fetched
//! in the offline build image, so this module keeps the artifact
//! *contract* — [`Runtime::load`] still requires `manifest.json` plus
//! the five HLO text files, and validates both — but executes the four
//! kernel artifacts (fused Adam, chunk reduction, LL pack/unpack) with
//! native implementations that are bit-compatible with the Pallas
//! kernels (cross-checked against `python/compile/kernels/ref.py` by
//! `python/tests/test_kernels.py`). The full transformer `train_step`
//! has no native twin yet; calling it returns a descriptive error until
//! a PJRT-capable build restores it.

pub mod manifest;

use anyhow::{anyhow, bail, ensure, Context, Result};
use manifest::Manifest;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

pub use manifest::{Json, ParamEntry};

/// Default artifacts directory: `artifacts/` at the repo root (the
/// package manifest lives in `rust/`, one level below), matching
/// `python/compile/aot.py`'s default `--out-dir ../artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("artifacts")
}

// Adam hyperparameters baked into the adam_step artifact
// (python/compile/kernels/fused_adam.py).
const LR: f32 = 1e-3;
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;

/// A loaded artifact runtime.
pub struct Runtime {
    pub manifest: Manifest,
    /// executions per artifact (observability)
    pub exec_counts: Mutex<std::collections::HashMap<&'static str, u64>>,
}

/// Cheap HLO-text well-formedness check (the same precondition the
/// PJRT text parser enforces before compilation).
fn check_artifact(dir: &Path, fname: &str) -> Result<()> {
    let path = dir.join(fname);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read HLO text {}", path.display()))?;
    ensure!(
        text.contains("HloModule") && text.contains("ENTRY"),
        "{} does not look like HLO text",
        path.display()
    );
    Ok(())
}

impl Runtime {
    /// Load and validate every artifact listed in the manifest.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .map_err(|e| anyhow!("manifest: {}", e))?;
        manifest.validate().map_err(|e| anyhow!("manifest invalid: {}", e))?;
        for key in ["train_step", "adam_step", "reduce_chunk", "ll_pack", "ll_unpack"] {
            let fname = manifest
                .artifacts
                .get(key)
                .ok_or_else(|| anyhow!("manifest missing artifact '{}'", key))?;
            check_artifact(dir, fname)?;
        }
        Ok(Runtime { manifest, exec_counts: Mutex::new(Default::default()) })
    }

    pub fn platform(&self) -> String {
        "cpu".to_string()
    }

    /// Whether this build can execute the transformer `train_step`
    /// artifact. False in the offline build (no PJRT executor); the
    /// train-dependent integration tests skip on it instead of
    /// failing once artifacts exist.
    pub fn train_executor_available(&self) -> bool {
        false
    }

    fn count(&self, what: &'static str) {
        *self.exec_counts.lock().unwrap().entry(what).or_insert(0) += 1;
    }

    /// One fwd/bwd step: returns (loss, flat gradients).
    ///
    /// Requires the PJRT executor, which the offline build does not
    /// ship — the kernel artifacts below run natively, the transformer
    /// step does not (yet).
    pub fn train_step(&self, flat_params: &[f32], x: &[i32], y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let m = &self.manifest;
        ensure!(flat_params.len() == m.n_params_padded, "bad param length");
        ensure!(x.len() == m.batch * m.seq_len, "bad x length");
        ensure!(y.len() == m.batch * m.seq_len, "bad y length");
        self.count("train_step");
        bail!(
            "train_step needs the PJRT/XLA executor, which is not part of this \
             offline build (the xla crate cannot be vendored); the adam/reduce/ll \
             kernel artifacts run natively — see DESIGN.md §PJRT"
        )
    }

    /// Fused Adam: returns (params', m', v'). Matches the adam_step
    /// artifact's math (fused_adam.py / ref.py) exactly: gradients are
    /// scaled by `grad_scale`, bias correction uses the 1-based `step`.
    pub fn adam_step(
        &self,
        p: &[f32],
        g: &[f32],
        m: &[f32],
        v: &[f32],
        step: f32,
        grad_scale: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let n = self.manifest.n_params_padded;
        ensure!(
            p.len() == n && g.len() == n && m.len() == n && v.len() == n,
            "adam_step buffers must all have the padded length {}",
            n
        );
        self.count("adam_step");
        let c1 = 1.0 - BETA1.powf(step);
        let c2 = 1.0 - BETA2.powf(step);
        let mut po = vec![0.0f32; n];
        let mut mo = vec![0.0f32; n];
        let mut vo = vec![0.0f32; n];
        for i in 0..n {
            let gi = g[i] * grad_scale;
            let mi = BETA1 * m[i] + (1.0 - BETA1) * gi;
            let vi = BETA2 * v[i] + (1.0 - BETA2) * gi * gi;
            let mhat = mi / c1;
            let vhat = vi / c2;
            po[i] = p[i] - LR * mhat / (vhat.sqrt() + EPS);
            mo[i] = mi;
            vo[i] = vi;
        }
        Ok((po, mo, vo))
    }

    /// Pallas chunk reduction at the fixed block size.
    pub fn reduce_block(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        ensure!(a.len() == self.manifest.reduce_block, "bad block length");
        ensure!(b.len() == self.manifest.reduce_block, "bad block length");
        self.count("reduce_chunk");
        Ok(a.iter().zip(b).map(|(x, y)| x + y).collect())
    }

    /// LL-protocol pack: f32[N] -> u32[2N] wire words, interleaving
    /// each data word with the flag (same layout as cc::proto::ll_pack
    /// and the Pallas ll_pack artifact).
    pub fn ll_pack(&self, data: &[f32], flag: u32) -> Result<Vec<u32>> {
        ensure!(data.len() == self.manifest.ll_block, "bad LL block");
        self.count("ll_pack");
        let mut wire = Vec::with_capacity(2 * data.len());
        for d in data {
            wire.push(d.to_bits());
            wire.push(flag);
        }
        Ok(wire)
    }

    /// LL-protocol unpack: (data, bad_lines). `bad_lines` counts flag
    /// words that did not match (0 iff the wire buffer is intact).
    pub fn ll_unpack(&self, wire: &[u32], flag: u32) -> Result<(Vec<f32>, u32)> {
        ensure!(wire.len() == 2 * self.manifest.ll_block, "bad LL wire");
        self.count("ll_unpack");
        let mut data = Vec::with_capacity(wire.len() / 2);
        let mut bad = 0u32;
        for line in wire.chunks_exact(2) {
            data.push(f32::from_bits(line[0]));
            if line[1] != flag {
                bad += 1;
            }
        }
        Ok((data, bad))
    }
}

/// A [`crate::cc::algo::Reducer`] backed by the `reduce_chunk`
/// artifact's executor: the ring reduce-scatter's combine runs through
/// the same block-tiled path a TPU deployment would use. Arbitrary
/// slice lengths are handled by zero-padding into the fixed block.
pub struct PallasReducer<'a> {
    pub rt: &'a Runtime,
}

impl crate::cc::algo::Reducer for PallasReducer<'_> {
    fn reduce_into(&self, acc: &mut [f32], src: &[f32]) {
        let block = self.rt.manifest.reduce_block;
        let mut abuf = vec![0.0f32; block];
        let mut bbuf = vec![0.0f32; block];
        let mut i = 0;
        while i < acc.len() {
            let n = (acc.len() - i).min(block);
            abuf[..n].copy_from_slice(&acc[i..i + n]);
            abuf[n..].fill(0.0);
            bbuf[..n].copy_from_slice(&src[i..i + n]);
            bbuf[n..].fill(0.0);
            let out = self.rt.reduce_block(&abuf, &bbuf).expect("pallas reduce");
            acc[i..i + n].copy_from_slice(&out[..n]);
            i += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A runtime with a synthetic manifest (no artifact files needed —
    /// the kernel executors are exercised directly).
    fn rt() -> Runtime {
        let text = r#"{
            "config": {"vocab": 256, "d_model": 16, "n_layers": 1,
                       "n_heads": 2, "seq_len": 8, "batch": 2},
            "n_params": 24,
            "n_params_padded": 32,
            "reduce_block": 16,
            "ll_block": 8,
            "params": [
                {"name": "embed", "shape": [4, 4], "offset": 0, "size": 16},
                {"name": "ln_f", "shape": [8], "offset": 16, "size": 8}
            ],
            "artifacts": {}
        }"#;
        let m = Manifest::parse(text).unwrap();
        m.validate().unwrap();
        Runtime { manifest: m, exec_counts: Mutex::new(Default::default()) }
    }

    #[test]
    fn adam_step_matches_reference_math() {
        let r = rt();
        let n = r.manifest.n_params_padded;
        let p = vec![1.0f32; n];
        let g = vec![0.5f32; n];
        let m = vec![0.0f32; n];
        let v = vec![0.0f32; n];
        let (po, mo, vo) = r.adam_step(&p, &g, &m, &v, 1.0, 1.0).unwrap();
        // step 1, m=v=0: mhat = g, vhat = g*g => p' = p - lr * g/(|g|+eps)
        let expect_p = 1.0 - LR * 0.5 / (0.5 + EPS);
        assert!((po[0] - expect_p).abs() < 1e-5, "{} vs {}", po[0], expect_p);
        assert!((mo[0] - 0.05).abs() < 1e-6);
        assert!((vo[0] - 0.00025).abs() < 1e-7);
        // grad_scale folds DDP averaging into the moment updates
        let (_, mo2, vo2) = r.adam_step(&p, &g, &m, &v, 1.0, 0.5).unwrap();
        assert!((mo2[0] - 0.025).abs() < 1e-6, "scaled grad halves m'");
        assert!(vo2[0] < vo[0], "scaled grad shrinks v'");
    }

    #[test]
    fn adam_descends_quadratic() {
        let r = rt();
        let n = r.manifest.n_params_padded;
        let mut p = vec![1.0f32; n];
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for step in 1..=50 {
            let g = p.clone();
            let (pn, mn, vn) = r.adam_step(&p, &g, &m, &v, step as f32, 1.0).unwrap();
            p = pn;
            m = mn;
            v = vn;
        }
        assert!(p[0].abs() < 0.96, "adam made no progress: {}", p[0]);
        assert!(p[0] > 0.5, "adam overshot: {}", p[0]);
    }

    #[test]
    fn reduce_block_is_elementwise_sum() {
        let r = rt();
        let n = r.manifest.reduce_block;
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let out = r.reduce_block(&a, &b).unwrap();
        for o in &out {
            assert_eq!(*o, n as f32);
        }
        assert!(r.reduce_block(&a[..4], &b).is_err());
    }

    #[test]
    fn ll_roundtrip_matches_engine_wire_layout() {
        let r = rt();
        let n = r.manifest.ll_block;
        let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 1.0).collect();
        let flag = 0x1234_5678u32;
        let wire = r.ll_pack(&data, flag).unwrap();

        // byte-identical to the engine's LL pack (proto.rs)
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        let mut rust_wire = Vec::new();
        crate::cc::proto::ll_pack(&bytes, flag, &mut rust_wire);
        let words: Vec<u32> = rust_wire
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(wire, words);

        let (out, bad) = r.ll_unpack(&wire, flag).unwrap();
        assert_eq!(bad, 0);
        assert_eq!(out, data);
        let mut corrupted = wire.clone();
        corrupted[1] ^= 0xff;
        let (_, bad) = r.ll_unpack(&corrupted, flag).unwrap();
        assert_eq!(bad, 1);
    }

    #[test]
    fn pallas_reducer_pads_odd_lengths() {
        let r = rt();
        let red = PallasReducer { rt: &r };
        for len in [1usize, 5, 16, 23, 40] {
            let mut acc: Vec<f32> = (0..len).map(|i| i as f32 * 0.1).collect();
            let src: Vec<f32> = (0..len).map(|i| (len - i) as f32 * 0.2).collect();
            let want: Vec<f32> = acc.iter().zip(&src).map(|(a, s)| a + s).collect();
            crate::cc::algo::Reducer::reduce_into(&red, &mut acc, &src);
            for (g, w) in acc.iter().zip(&want) {
                assert!((g - w).abs() < 1e-6, "len {}", len);
            }
        }
    }

    #[test]
    fn train_step_reports_missing_executor() {
        let r = rt();
        let p = vec![0.0f32; r.manifest.n_params_padded];
        let x = vec![0i32; r.manifest.batch * r.manifest.seq_len];
        let e = r.train_step(&p, &x, &x).unwrap_err();
        assert!(e.to_string().contains("PJRT"), "{}", e);
    }

    #[test]
    fn load_requires_artifacts() {
        let dir = std::env::temp_dir().join("ncclbpf_rt_missing");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Runtime::load(&dir).is_err());
    }
}
