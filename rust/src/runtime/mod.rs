//! PJRT runtime: load the AOT artifacts (HLO text) produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//! Python never runs at request time — the binary is self-contained
//! once `make artifacts` has produced `artifacts/`.

pub mod manifest;

use anyhow::{anyhow, Context, Result};
use manifest::Manifest;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

pub use manifest::{Json, ParamEntry};

/// Default artifacts directory (repo-relative).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A loaded PJRT runtime with every executable compiled once.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    train_step: xla::PjRtLoadedExecutable,
    adam_step: xla::PjRtLoadedExecutable,
    reduce_chunk: xla::PjRtLoadedExecutable,
    ll_pack: xla::PjRtLoadedExecutable,
    ll_unpack: xla::PjRtLoadedExecutable,
    /// executions per artifact (observability)
    pub exec_counts: Mutex<std::collections::HashMap<&'static str, u64>>,
}

fn compile_artifact(
    client: &xla::PjRtClient,
    dir: &Path,
    fname: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(fname);
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .with_context(|| format!("parse HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compile {}", fname))
}

impl Runtime {
    /// Load and compile every artifact listed in the manifest.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .map_err(|e| anyhow!("manifest: {}", e))?;
        manifest.validate().map_err(|e| anyhow!("manifest invalid: {}", e))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let get = |k: &str| -> Result<String> {
            manifest
                .artifacts
                .get(k)
                .cloned()
                .ok_or_else(|| anyhow!("manifest missing artifact '{}'", k))
        };
        Ok(Runtime {
            train_step: compile_artifact(&client, dir, &get("train_step")?)?,
            adam_step: compile_artifact(&client, dir, &get("adam_step")?)?,
            reduce_chunk: compile_artifact(&client, dir, &get("reduce_chunk")?)?,
            ll_pack: compile_artifact(&client, dir, &get("ll_pack")?)?,
            ll_unpack: compile_artifact(&client, dir, &get("ll_unpack")?)?,
            client,
            manifest,
            exec_counts: Mutex::new(Default::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn count(&self, what: &'static str) {
        *self.exec_counts.lock().unwrap().entry(what).or_insert(0) += 1;
    }

    /// One fwd/bwd step: returns (loss, flat gradients).
    pub fn train_step(&self, flat_params: &[f32], x: &[i32], y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let m = &self.manifest;
        anyhow::ensure!(flat_params.len() == m.n_params_padded, "bad param length");
        anyhow::ensure!(x.len() == m.batch * m.seq_len, "bad x length");
        anyhow::ensure!(y.len() == m.batch * m.seq_len, "bad y length");
        let p = xla::Literal::vec1(flat_params);
        let xs = xla::Literal::vec1(x).reshape(&[m.batch as i64, m.seq_len as i64])?;
        let ys = xla::Literal::vec1(y).reshape(&[m.batch as i64, m.seq_len as i64])?;
        self.count("train_step");
        let result =
            self.train_step.execute::<xla::Literal>(&[p, xs, ys])?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 2, "train_step must return (loss, grads)");
        let loss = parts[0].to_vec::<f32>()?[0];
        let grads = parts[1].to_vec::<f32>()?;
        Ok((loss, grads))
    }

    /// Fused Adam: returns (params', m', v').
    pub fn adam_step(
        &self,
        p: &[f32],
        g: &[f32],
        m: &[f32],
        v: &[f32],
        step: f32,
        grad_scale: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let sc = xla::Literal::vec1(&[step, grad_scale]);
        self.count("adam_step");
        let result = self
            .adam_step
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(p),
                xla::Literal::vec1(g),
                xla::Literal::vec1(m),
                xla::Literal::vec1(v),
                sc,
            ])?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 3, "adam_step must return (p, m, v)");
        Ok((
            parts[0].to_vec::<f32>()?,
            parts[1].to_vec::<f32>()?,
            parts[2].to_vec::<f32>()?,
        ))
    }

    /// Pallas chunk reduction at the fixed block size.
    pub fn reduce_block(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(a.len() == self.manifest.reduce_block, "bad block length");
        self.count("reduce_chunk");
        let result = self
            .reduce_chunk
            .execute::<xla::Literal>(&[xla::Literal::vec1(a), xla::Literal::vec1(b)])?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// LL-protocol pack via the Pallas artifact.
    pub fn ll_pack(&self, data: &[f32], flag: u32) -> Result<Vec<u32>> {
        anyhow::ensure!(data.len() == self.manifest.ll_block, "bad LL block");
        self.count("ll_pack");
        let result = self
            .ll_pack
            .execute::<xla::Literal>(&[xla::Literal::vec1(data), xla::Literal::scalar(flag)])?
            [0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<u32>()?)
    }

    /// LL-protocol unpack via the Pallas artifact: (data, bad_lines).
    pub fn ll_unpack(&self, wire: &[u32], flag: u32) -> Result<(Vec<f32>, u32)> {
        anyhow::ensure!(wire.len() == 2 * self.manifest.ll_block, "bad LL wire");
        self.count("ll_unpack");
        let result = self
            .ll_unpack
            .execute::<xla::Literal>(&[xla::Literal::vec1(wire), xla::Literal::scalar(flag)])?
            [0][0]
            .to_literal_sync()?;
        let (data, bad) = result.to_tuple2()?;
        Ok((data.to_vec::<f32>()?, bad.to_vec::<u32>()?[0]))
    }
}

/// A [`crate::cc::algo::Reducer`] backed by the Pallas `reduce_chunk`
/// artifact: the ring reduce-scatter's combine runs through the same
/// compiled kernel a TPU deployment would use. Arbitrary slice lengths
/// are handled by zero-padding into the fixed block.
pub struct PallasReducer<'a> {
    pub rt: &'a Runtime,
}

impl crate::cc::algo::Reducer for PallasReducer<'_> {
    fn reduce_into(&self, acc: &mut [f32], src: &[f32]) {
        let block = self.rt.manifest.reduce_block;
        let mut abuf = vec![0.0f32; block];
        let mut bbuf = vec![0.0f32; block];
        let mut i = 0;
        while i < acc.len() {
            let n = (acc.len() - i).min(block);
            abuf[..n].copy_from_slice(&acc[i..i + n]);
            abuf[n..].fill(0.0);
            bbuf[..n].copy_from_slice(&src[i..i + n]);
            bbuf[n..].fill(0.0);
            let out = self.rt.reduce_block(&abuf, &bbuf).expect("pallas reduce");
            acc[i..i + n].copy_from_slice(&out[..n]);
            i += n;
        }
    }
}
