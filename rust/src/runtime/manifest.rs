//! Artifact manifest parsing (artifacts/manifest.json) — includes a
//! minimal JSON parser (serde is not in the offline crate set).

use std::collections::HashMap;
use std::path::Path;

/// Minimal JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => Some(*n as u64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

pub fn parse_json(src: &str) -> Result<Json, String> {
    let chars: Vec<char> = src.chars().collect();
    let mut pos = 0usize;
    let v = parse_value(&chars, &mut pos)?;
    skip_ws(&chars, &mut pos);
    if pos != chars.len() {
        return Err(format!("trailing JSON at char {}", pos));
    }
    Ok(v)
}

fn skip_ws(c: &[char], p: &mut usize) {
    while *p < c.len() && c[*p].is_whitespace() {
        *p += 1;
    }
}

fn parse_value(c: &[char], p: &mut usize) -> Result<Json, String> {
    skip_ws(c, p);
    match c.get(*p) {
        None => Err("unexpected end of JSON".into()),
        Some('{') => {
            *p += 1;
            let mut m = HashMap::new();
            skip_ws(c, p);
            if c.get(*p) == Some(&'}') {
                *p += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(c, p);
                let Json::Str(key) = parse_value(c, p)? else {
                    return Err("object key must be a string".into());
                };
                skip_ws(c, p);
                if c.get(*p) != Some(&':') {
                    return Err(format!("expected ':' at char {}", p));
                }
                *p += 1;
                let v = parse_value(c, p)?;
                m.insert(key, v);
                skip_ws(c, p);
                match c.get(*p) {
                    Some(',') => {
                        *p += 1;
                    }
                    Some('}') => {
                        *p += 1;
                        return Ok(Json::Obj(m));
                    }
                    other => return Err(format!("expected ',' or '}}', got {:?}", other)),
                }
            }
        }
        Some('[') => {
            *p += 1;
            let mut a = Vec::new();
            skip_ws(c, p);
            if c.get(*p) == Some(&']') {
                *p += 1;
                return Ok(Json::Arr(a));
            }
            loop {
                a.push(parse_value(c, p)?);
                skip_ws(c, p);
                match c.get(*p) {
                    Some(',') => {
                        *p += 1;
                    }
                    Some(']') => {
                        *p += 1;
                        return Ok(Json::Arr(a));
                    }
                    other => return Err(format!("expected ',' or ']', got {:?}", other)),
                }
            }
        }
        Some('"') => {
            *p += 1;
            let mut s = String::new();
            while let Some(&ch) = c.get(*p) {
                *p += 1;
                match ch {
                    '"' => return Ok(Json::Str(s)),
                    '\\' => {
                        let esc = c.get(*p).copied().ok_or("bad escape")?;
                        *p += 1;
                        s.push(match esc {
                            'n' => '\n',
                            't' => '\t',
                            'r' => '\r',
                            other => other,
                        });
                    }
                    other => s.push(other),
                }
            }
            Err("unterminated string".into())
        }
        Some('t') => {
            if c[*p..].starts_with(&['t', 'r', 'u', 'e']) {
                *p += 4;
                Ok(Json::Bool(true))
            } else {
                Err("bad literal".into())
            }
        }
        Some('f') => {
            if c[*p..].starts_with(&['f', 'a', 'l', 's', 'e']) {
                *p += 5;
                Ok(Json::Bool(false))
            } else {
                Err("bad literal".into())
            }
        }
        Some('n') => {
            if c[*p..].starts_with(&['n', 'u', 'l', 'l']) {
                *p += 4;
                Ok(Json::Null)
            } else {
                Err("bad literal".into())
            }
        }
        Some(_) => {
            let start = *p;
            while *p < c.len()
                && (c[*p].is_ascii_digit() || matches!(c[*p], '-' | '+' | '.' | 'e' | 'E'))
            {
                *p += 1;
            }
            let s: String = c[start..*p].iter().collect();
            s.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{}'", s))
        }
    }
}

/// One parameter tensor's place in the flat vector.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Model/runtime configuration exported by aot.py.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_params: usize,
    pub n_params_padded: usize,
    pub reduce_block: usize,
    pub ll_block: usize,
    pub params: Vec<ParamEntry>,
    pub artifacts: HashMap<String, String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {}: {}", path.display(), e))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = parse_json(text)?;
        let cfg = j.get("config").ok_or("manifest missing 'config'")?;
        let u = |v: Option<&Json>, what: &str| -> Result<usize, String> {
            v.and_then(Json::as_u64)
                .map(|x| x as usize)
                .ok_or(format!("manifest missing {}", what))
        };
        let mut params = Vec::new();
        for p in j.get("params").and_then(Json::as_arr).ok_or("missing params")? {
            params.push(ParamEntry {
                name: p.get("name").and_then(Json::as_str).ok_or("param name")?.to_string(),
                shape: p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or("param shape")?
                    .iter()
                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                    .collect(),
                offset: u(p.get("offset"), "param offset")?,
                size: u(p.get("size"), "param size")?,
            });
        }
        let mut artifacts = HashMap::new();
        if let Some(Json::Obj(m)) = j.get("artifacts") {
            for (k, v) in m {
                if let Json::Str(s) = v {
                    artifacts.insert(k.clone(), s.clone());
                }
            }
        }
        Ok(Manifest {
            vocab: u(cfg.get("vocab"), "vocab")?,
            d_model: u(cfg.get("d_model"), "d_model")?,
            n_layers: u(cfg.get("n_layers"), "n_layers")?,
            n_heads: u(cfg.get("n_heads"), "n_heads")?,
            seq_len: u(cfg.get("seq_len"), "seq_len")?,
            batch: u(cfg.get("batch"), "batch")?,
            n_params: u(j.get("n_params"), "n_params")?,
            n_params_padded: u(j.get("n_params_padded"), "n_params_padded")?,
            reduce_block: u(j.get("reduce_block"), "reduce_block")?,
            ll_block: u(j.get("ll_block"), "ll_block")?,
            params,
            artifacts,
        })
    }

    /// Consistency checks mirroring python/tests/test_aot.py.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_params_padded % self.reduce_block != 0 {
            return Err("padded size not a block multiple".into());
        }
        let mut off = 0;
        for p in &self.params {
            if p.offset != off {
                return Err(format!("param '{}' offset {} != expected {}", p.name, p.offset, off));
            }
            let sz: usize = p.shape.iter().product();
            if sz != p.size {
                return Err(format!("param '{}' size mismatch", p.name));
            }
            off += p.size;
        }
        if off != self.n_params {
            return Err(format!("param sizes sum {} != n_params {}", off, self.n_params));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parser_basics() {
        let j = parse_json(r#"{"a": 1, "b": [1, 2.5, "x"], "c": {"d": true}, "e": null}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().get("d"), Some(&Json::Bool(true)));
        assert_eq!(j.get("e"), Some(&Json::Null));
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("{} extra").is_err());
    }

    #[test]
    fn json_negative_and_exponent() {
        let j = parse_json("[-3, 1e3, 2.5e-2]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0], Json::Num(-3.0));
        assert_eq!(a[1], Json::Num(1000.0));
    }

    #[test]
    fn manifest_roundtrip() {
        let text = r#"{
            "config": {"vocab": 256, "d_model": 128, "n_layers": 4,
                       "n_heads": 4, "seq_len": 64, "batch": 4},
            "n_params": 20,
            "n_params_padded": 16384,
            "reduce_block": 16384,
            "ll_block": 8192,
            "params": [
                {"name": "a", "shape": [4, 4], "offset": 0, "size": 16},
                {"name": "b", "shape": [4], "offset": 16, "size": 4}
            ],
            "artifacts": {"train_step": "train_step.hlo.txt"}
        }"#;
        let m = Manifest::parse(text).unwrap();
        m.validate().unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[1].offset, 16);
        assert_eq!(m.artifacts["train_step"], "train_step.hlo.txt");
    }

    #[test]
    fn manifest_validation_catches_gaps() {
        let text = r#"{
            "config": {"vocab": 1, "d_model": 1, "n_layers": 1,
                       "n_heads": 1, "seq_len": 1, "batch": 1},
            "n_params": 20, "n_params_padded": 16384,
            "reduce_block": 16384, "ll_block": 8192,
            "params": [{"name": "a", "shape": [16], "offset": 4, "size": 16}],
            "artifacts": {}
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert!(m.validate().is_err());
    }
}
