//! Native-Rust baseline policies (§4 "Native baseline for comparison"):
//! identical policy logic to the eBPF programs, compiled as ordinary
//! optimized native code. The Table 1 bench measures the delta between
//! these and the eBPF versions to isolate the dispatch + JIT layer cost
//! from the policy logic cost.

use crate::cc::plugin::{CollInfoArgs, CostTable, TunerPlugin};
use crate::cc::{Algo, Proto};
use std::sync::atomic::{AtomicU64, Ordering};

/// Native twin of `policies/noop.c`: returns immediately.
pub struct NativeNoop;

impl TunerPlugin for NativeNoop {
    fn name(&self) -> &str {
        "native_noop"
    }
    #[inline]
    fn get_coll_info(&self, _a: &CollInfoArgs, _c: &mut CostTable, _n: &mut u32) {}
}

/// Native twin of `policies/static_ring.c`.
pub struct NativeStaticRing;

impl TunerPlugin for NativeStaticRing {
    fn name(&self) -> &str {
        "native_static_ring"
    }
    #[inline]
    fn get_coll_info(&self, _a: &CollInfoArgs, cost: &mut CostTable, n: &mut u32) {
        cost.prefer(Algo::Ring, Proto::Simple);
        *n = 32;
    }
}

/// Native twin of `policies/size_aware.c` (the paper's Listing 1 shape:
/// tree for <=32 KiB, ring above, Simple protocol).
pub struct NativeSizeAware;

impl TunerPlugin for NativeSizeAware {
    fn name(&self) -> &str {
        "native_size_aware"
    }
    #[inline]
    fn get_coll_info(&self, a: &CollInfoArgs, cost: &mut CostTable, n: &mut u32) {
        if a.nbytes <= 32 * 1024 {
            cost.prefer(Algo::Tree, Proto::Ll);
        } else {
            cost.prefer(Algo::Ring, Proto::Simple);
        }
        *n = 16;
    }
}

/// Native twin of `policies/nvlink_ring_mid_v2.c` — the §5.3 case-study
/// policy: Ring/LL128 for 4–32 MiB, Ring/Simple for 64–192 MiB, defer
/// to the engine default otherwise.
pub struct NativeRingMidV2;

impl TunerPlugin for NativeRingMidV2 {
    fn name(&self) -> &str {
        "native_nvlink_ring_mid_v2"
    }
    #[inline]
    fn get_coll_info(&self, a: &CollInfoArgs, cost: &mut CostTable, n: &mut u32) {
        const MIB: usize = 1 << 20;
        if (4 * MIB..=32 * MIB).contains(&a.nbytes) {
            cost.prefer(Algo::Ring, Proto::Ll128);
            *n = 32;
        } else if (64 * MIB..=192 * MIB).contains(&a.nbytes) {
            cost.prefer(Algo::Ring, Proto::Simple);
            *n = 32;
        }
        // otherwise defer to NCCL's default (NVLS)
    }
}

/// Native twin of `policies/adaptive_channels.c`: stateful (one shared
/// cell standing in for the eBPF map) — reads last observed latency and
/// nudges the channel count, writing back its decision.
pub struct NativeAdaptive {
    /// last observed latency (the "map" the profiler twin would write)
    pub latency_ns: AtomicU64,
    /// current channel decision
    pub channels: AtomicU64,
}

impl Default for NativeAdaptive {
    fn default() -> Self {
        NativeAdaptive { latency_ns: AtomicU64::new(0), channels: AtomicU64::new(2) }
    }
}

impl TunerPlugin for NativeAdaptive {
    fn name(&self) -> &str {
        "native_adaptive"
    }
    #[inline]
    fn get_coll_info(&self, _a: &CollInfoArgs, cost: &mut CostTable, n: &mut u32) {
        let lat = self.latency_ns.load(Ordering::Relaxed); // "map lookup"
        let ch = self.channels.load(Ordering::Relaxed);
        let next = if lat > 1_000_000 { (ch + 1).min(16) } else { ch };
        self.channels.store(next, Ordering::Relaxed); // "map update"
        cost.prefer(Algo::Ring, Proto::Simple);
        *n = next as u32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{CollType, MAX_CHANNELS};

    fn args(nbytes: usize) -> CollInfoArgs {
        CollInfoArgs {
            coll: CollType::AllReduce,
            nbytes,
            nranks: 8,
            comm_id: 1,
            max_channels: MAX_CHANNELS,
        }
    }

    #[test]
    fn size_aware_switches_at_32k() {
        let p = NativeSizeAware;
        let mut c = CostTable::all_sentinel();
        let mut n = 0;
        p.get_coll_info(&args(16 << 10), &mut c, &mut n);
        assert_eq!(c.argmin(), Some((Algo::Tree, Proto::Ll)));
        let mut c = CostTable::all_sentinel();
        p.get_coll_info(&args(1 << 20), &mut c, &mut n);
        assert_eq!(c.argmin(), Some((Algo::Ring, Proto::Simple)));
    }

    #[test]
    fn ring_mid_v2_ranges() {
        let p = NativeRingMidV2;
        let mib = 1usize << 20;
        for (size, expect) in [
            (2 * mib, None),
            (4 * mib, Some((Algo::Ring, Proto::Ll128))),
            (32 * mib, Some((Algo::Ring, Proto::Ll128))),
            (64 * mib, Some((Algo::Ring, Proto::Simple))),
            (192 * mib, Some((Algo::Ring, Proto::Simple))),
            (256 * mib, None),
        ] {
            let mut c = CostTable::all_sentinel();
            let mut n = 0;
            p.get_coll_info(&args(size), &mut c, &mut n);
            assert_eq!(c.argmin(), expect, "size {}", size);
        }
    }

    #[test]
    fn adaptive_ramps_on_high_latency() {
        let p = NativeAdaptive::default();
        let mut n = 0;
        let mut c = CostTable::all_sentinel();
        p.get_coll_info(&args(1 << 20), &mut c, &mut n);
        assert_eq!(n, 2);
        p.latency_ns.store(5_000_000, Ordering::Relaxed);
        for _ in 0..20 {
            p.get_coll_info(&args(1 << 20), &mut c, &mut n);
        }
        assert_eq!(n, 16); // capped
    }
}
