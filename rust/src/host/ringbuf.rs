//! Host-side ring-buffer consumer: the userspace half of the event
//! streaming channel (`bpf_ringbuf_*` is the producer half, run by
//! verified policies).
//!
//! [`RingConsumer`] wraps a [`MapKind::RingBuf`](crate::bpf::MapKind)
//! map and drains completed records with acquire ordering (see the
//! memory-model notes on [`Map::ringbuf_drain`]); it owns the
//! single-consumer role, tracks how many records it delivered, and
//! reads the producer-side drop counter so callers can check the
//! end-to-end conservation invariant `drained + dropped == emitted`.
//!
//! [`RbEvent`] is the 32-byte structured latency record the
//! `latency_events` profiler policy emits — the payload `ncclbpf trace`
//! streams and the closed-loop driver averages back into
//! `latency_map` for an adaptive tuner (the paper's §5.3 loop, with a
//! ring instead of a scalar map slot as the telemetry channel).

use crate::bpf::{Map, MapKind};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Size of one [`RbEvent`] on the wire.
pub const RB_EVENT_SIZE: usize = 32;

/// Structured latency event emitted by the `latency_events` profiler
/// policy (field order is ABI, mirrored in `policies/latency_events.c`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RbEvent {
    /// folded communicator id
    pub comm_id: u32,
    /// collective type index
    pub coll_type: u32,
    /// message size in bytes
    pub msg_size: u64,
    /// observed collective latency
    pub latency_ns: u64,
    /// channels the collective ran with
    pub n_channels: u32,
    /// per-communicator sequence number
    pub seq: u32,
}

impl RbEvent {
    /// Decode one record payload; `None` if the length is wrong.
    pub fn parse(b: &[u8]) -> Option<RbEvent> {
        if b.len() != RB_EVENT_SIZE {
            return None;
        }
        let u32_at = |o: usize| u32::from_le_bytes(b[o..o + 4].try_into().unwrap());
        let u64_at = |o: usize| u64::from_le_bytes(b[o..o + 8].try_into().unwrap());
        Some(RbEvent {
            comm_id: u32_at(0),
            coll_type: u32_at(4),
            msg_size: u64_at(8),
            latency_ns: u64_at(16),
            n_channels: u32_at(24),
            seq: u32_at(28),
        })
    }

    /// Encode to the wire layout (tests, synthetic producers).
    pub fn to_bytes(&self) -> [u8; RB_EVENT_SIZE] {
        let mut out = [0u8; RB_EVENT_SIZE];
        out[0..4].copy_from_slice(&self.comm_id.to_le_bytes());
        out[4..8].copy_from_slice(&self.coll_type.to_le_bytes());
        out[8..16].copy_from_slice(&self.msg_size.to_le_bytes());
        out[16..24].copy_from_slice(&self.latency_ns.to_le_bytes());
        out[24..28].copy_from_slice(&self.n_channels.to_le_bytes());
        out[28..32].copy_from_slice(&self.seq.to_le_bytes());
        out
    }

    /// One JSON line (for `ncclbpf trace --json`).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"comm_id\":{},\"coll_type\":{},\"msg_size\":{},\"latency_ns\":{},\
             \"n_channels\":{},\"seq\":{}}}",
            self.comm_id, self.coll_type, self.msg_size, self.latency_ns, self.n_channels,
            self.seq
        )
    }
}

/// The single consumer of one ring-buffer map.
pub struct RingConsumer {
    map: Arc<Map>,
    /// records delivered to callbacks over this consumer's lifetime
    pub drained: u64,
}

impl RingConsumer {
    /// Wrap `map`; errors if it is not a ringbuf map.
    pub fn new(map: Arc<Map>) -> Result<RingConsumer, String> {
        if map.def.kind != MapKind::RingBuf {
            return Err(format!(
                "map '{}' is {:?}, not a ringbuf map",
                map.def.name, map.def.kind
            ));
        }
        Ok(RingConsumer { map, drained: 0 })
    }

    /// Drain every completed record into `cb`; returns how many were
    /// delivered this pass.
    pub fn drain(&mut self, mut cb: impl FnMut(&[u8])) -> usize {
        let n = self.map.ringbuf_drain(&mut cb);
        self.drained += n as u64;
        n
    }

    /// Drain, decoding each record as an [`RbEvent`] (records of the
    /// wrong size are handed to nobody and counted as `malformed`).
    pub fn drain_events(&mut self, mut cb: impl FnMut(RbEvent)) -> (usize, usize) {
        let mut malformed = 0usize;
        let n = self.drain(|b| match RbEvent::parse(b) {
            Some(ev) => cb(ev),
            None => malformed += 1,
        });
        (n - malformed, malformed)
    }

    /// Keep draining until `stop` is observed set AND the ring is
    /// empty, yielding between empty passes — the consumer-thread loop
    /// shared by the traffic engine and the ringbuf bench. One final
    /// sweep runs after `stop` so records submitted just before the
    /// producers finished are never abandoned. Returns the number of
    /// records delivered during this call.
    pub fn drain_until(&mut self, stop: &AtomicBool, mut cb: impl FnMut(&[u8])) -> u64 {
        let start = self.drained;
        loop {
            let n = self.drain(&mut cb);
            if n == 0 {
                if stop.load(Ordering::Acquire) {
                    self.drain(&mut cb);
                    return self.drained - start;
                }
                std::thread::yield_now();
            }
        }
    }

    /// Producer-side drops (failed reservations) since map creation.
    pub fn dropped(&self) -> u64 {
        self.map.ringbuf_dropped()
    }

    /// Records skipped because the producer discarded them (counted so
    /// conservation checks can close the books even for
    /// reserve+discard policies).
    pub fn discarded(&self) -> u64 {
        self.map.ringbuf_discarded()
    }

    /// Unconsumed bytes currently in the ring.
    pub fn backlog_bytes(&self) -> u64 {
        self.map.ringbuf_query(crate::bpf::maps::ringbuf_query::AVAIL_DATA)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpf::maps::MapDef;
    use crate::cc::plugin::{CostTable, ProfilerEvent};
    use crate::cc::{Algo, CollConfig, CollType, Proto};
    use crate::host::NcclBpfHost;
    use std::sync::atomic::Ordering;

    fn ring_map(size: u32) -> Arc<Map> {
        Arc::new(
            Map::new(
                MapDef {
                    name: "rb".into(),
                    kind: MapKind::RingBuf,
                    key_size: 0,
                    value_size: 0,
                    max_entries: size,
                },
                1,
            )
            .unwrap(),
        )
    }

    #[test]
    fn consumer_requires_ringbuf_kind() {
        let m = Arc::new(
            Map::new(
                MapDef {
                    name: "a".into(),
                    kind: MapKind::Array,
                    key_size: 4,
                    value_size: 8,
                    max_entries: 1,
                },
                1,
            )
            .unwrap(),
        );
        assert!(RingConsumer::new(m).is_err());
        assert!(RingConsumer::new(ring_map(4096)).is_ok());
    }

    #[test]
    fn event_roundtrip_and_conservation() {
        let m = ring_map(256); // 40 bytes/record -> 6 fit
        let mut c = RingConsumer::new(m.clone()).unwrap();
        let ev = RbEvent {
            comm_id: 7,
            coll_type: 0,
            msg_size: 1 << 20,
            latency_ns: 123_456,
            n_channels: 8,
            seq: 3,
        };
        let mut emitted = 0u64;
        for _ in 0..10 {
            if m.ringbuf_output(&ev.to_bytes()) == 0 {
                emitted += 1;
            }
        }
        let mut got = Vec::new();
        let (okn, bad) = c.drain_events(|e| got.push(e));
        assert_eq!(bad, 0);
        assert_eq!(okn as u64, emitted);
        assert_eq!(got[0], ev);
        // conservation: everything emitted was drained or dropped
        assert_eq!(c.drained + c.dropped(), 10);
        assert!(c.dropped() > 0, "a 256-byte ring cannot hold 10 events");
        assert_eq!(c.backlog_bytes(), 0);
        // malformed records are counted, not delivered
        m.ringbuf_output(&[0u8; 8]);
        let (okn, bad) = c.drain_events(|_| panic!("short record must not decode"));
        assert_eq!((okn, bad), (0, 1));
        assert!(ev.to_json().contains("\"latency_ns\":123456"));
    }

    #[test]
    fn drain_until_final_sweep_conserves() {
        let m = ring_map(4096);
        let stop = Arc::new(AtomicBool::new(false));
        let consumer = {
            let m = m.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut c = RingConsumer::new(m).unwrap();
                c.drain_until(&stop, |_| {})
            })
        };
        for i in 0..200u64 {
            // retry on transient full: the consumer is catching up
            while m.ringbuf_output(&i.to_le_bytes()) != 0 {
                std::thread::yield_now();
            }
        }
        stop.store(true, Ordering::Release);
        assert_eq!(consumer.join().unwrap(), 200, "final sweep must catch the tail");
    }

    /// The tentpole's composable-policy demonstration: an
    /// event-emitting profiler (ringbuf producer) + a host drain loop
    /// feeding the shared `latency_map` + the stock adaptive tuner.
    /// Three independently deployed pieces close the §5.3 loop through
    /// structured events instead of a scalar slot.
    #[test]
    fn closed_loop_profiler_ring_host_tuner() {
        let host = NcclBpfHost::new();
        host.install_object(&crate::host::policydir::build_named("latency_events").unwrap())
            .expect("latency_events must verify");
        host.install_object(&crate::host::policydir::build_named("adaptive_channels").unwrap())
            .expect("adaptive_channels must verify");
        let mut consumer =
            RingConsumer::new(host.map("events").expect("ring map registered")).unwrap();
        let latency_map = host.map("latency_map").expect("shared map registered");

        let feed = |latency_ns: u64, seq: u64| ProfilerEvent::CollEnd {
            comm_id: 7,
            seq,
            coll: CollType::AllReduce,
            nbytes: 1 << 20,
            cfg: CollConfig::new(Algo::Ring, Proto::Simple, 8),
            ts_ns: 0,
            latency_ns,
        };
        let decide = |host: &NcclBpfHost| {
            let mut cost = CostTable::all_sentinel();
            let mut ch = 0u32;
            host.tuner_decide(
                &crate::cc::plugin::CollInfoArgs {
                    coll: CollType::AllReduce,
                    nbytes: 1 << 20,
                    nranks: 8,
                    comm_id: 7,
                    max_channels: 32,
                },
                &mut cost,
                &mut ch,
            );
            ch
        };

        // no events drained yet -> tuner sees an empty latency_map
        assert_eq!(decide(&host), 2, "no telemetry: conservative channels");

        // healthy latencies stream through the ring; the host loop
        // aggregates them into latency_map (value = [avg_latency, chans])
        for seq in 0..8 {
            host.profiler_handle(&feed(400_000, seq));
        }
        let mut sum = 0u64;
        let mut n = 0u64;
        let mut chans = 0u64;
        consumer.drain_events(|e| {
            sum += e.latency_ns;
            n += 1;
            chans = e.n_channels as u64;
        });
        assert_eq!(n, 8, "all profiler events must stream through the ring");
        let comm_key = crate::host::fold_comm_id(7);
        let mut value = [0u8; 16];
        value[..8].copy_from_slice(&(sum / n).to_le_bytes());
        value[8..].copy_from_slice(&chans.to_le_bytes());
        latency_map.update(&comm_key.to_le_bytes(), &value).unwrap();
        assert_eq!(decide(&host), 12, "healthy latency: tuner ramps channels");

        // a contention spike flows around the same loop and backs off
        for seq in 8..10 {
            host.profiler_handle(&feed(5_000_000, seq));
        }
        let mut worst = 0u64;
        consumer.drain_events(|e| worst = worst.max(e.latency_ns));
        value[..8].copy_from_slice(&worst.to_le_bytes());
        latency_map.update(&comm_key.to_le_bytes(), &value).unwrap();
        assert_eq!(decide(&host), 2, "contention: tuner backs off");

        // conservation held throughout
        assert_eq!(
            consumer.drained + consumer.dropped(),
            host.prof_events.load(Ordering::Relaxed)
        );
    }
}
