//! Host-wide introspection: one consistent-enough snapshot of every
//! installed program, shared map, hook slot, and recent reload — the
//! shape behind `ncclbpf stats` / `ncclbpf top` (DESIGN.md §13).
//!
//! Two host-side records feed the snapshot:
//!
//! - the **install ledger**: one entry per program the host ever
//!   installed (hook slots and prog-array chain links alike), holding
//!   a `Weak` handle to the program plus a strong clone of its
//!   [`RunStatsCell`] — so run counts survive hot-reload retirement
//!   and conservation invariants (`sum(run_cnt) == decisions`) hold
//!   across reload storms. The ledger is bounded: past
//!   [`LEDGER_CAP`] entries, dead programs are folded into one
//!   per-hook [`RunStats`] aggregate.
//! - the **reload journal**: a bounded ring of the last
//!   [`JOURNAL_CAP`] hook-slot swaps with their full load-phase
//!   timing (verify → analyze → compile → swap), the `bpftool prog
//!   list`-meets-audit-log surface.
//!
//! Consistency: counters are relaxed atomics read without a global
//! pause, so a snapshot is monotone per counter but not an atomic cut
//! across counters — the same contract as [`RunStatsCell::aggregate`].

use crate::bpf::stats::{MapPressureStats, RunStats, RunStatsCell};
use crate::bpf::{JitInlineStats, LoadedProgram, MapKind, ProgType};
use std::sync::{Arc, Weak};

/// Ledger bound: past this many entries, dead programs are compacted
/// into the per-hook retired aggregate.
pub const LEDGER_CAP: usize = 256;

/// Journal bound: swaps beyond this evict the oldest entry.
pub const JOURNAL_CAP: usize = 64;

/// Dense index for per-hook arrays (`[T; 3]` keyed by [`ProgType`]).
pub(crate) fn hook_idx(pt: ProgType) -> usize {
    match pt {
        ProgType::Tuner => 0,
        ProgType::Profiler => 1,
        ProgType::Net => 2,
    }
}

/// The three hook types in `hook_idx` order.
pub(crate) const HOOKS: [ProgType; 3] = [ProgType::Tuner, ProgType::Profiler, ProgType::Net];

/// One install the host performed (hook slot or chain link).
pub(crate) struct LedgerEntry {
    pub(crate) name: String,
    pub(crate) prog_type: ProgType,
    pub(crate) insns: usize,
    pub(crate) max_cost: u64,
    pub(crate) jitted: bool,
    pub(crate) inline_stats: Option<JitInlineStats>,
    /// strong clone of the program's run-stat cell: counts outlive the
    /// program across hot-reload retirement
    pub(crate) cell: Option<Arc<RunStatsCell>>,
    /// liveness probe — `upgrade()` fails once every hook slot,
    /// prog-array slot, and in-flight execution has dropped it
    pub(crate) prog: Weak<LoadedProgram>,
}

/// The bounded install ledger plus the per-hook compaction aggregates.
#[derive(Default)]
pub(crate) struct InstallLedger {
    pub(crate) entries: Vec<LedgerEntry>,
    /// run stats folded out of compacted (dead) entries, per hook
    pub(crate) retired_run: [RunStats; 3],
    /// how many installs were compacted away, per hook
    pub(crate) retired_installs: [u64; 3],
}

impl InstallLedger {
    /// Append one install, refusing duplicates of a still-tracked
    /// program (re-installing the same `Arc` must not double-count its
    /// shared stat cell) and compacting dead entries past the cap.
    pub(crate) fn record(&mut self, prog: &Arc<LoadedProgram>) {
        if self.entries.iter().any(|e| std::ptr::eq(e.prog.as_ptr(), Arc::as_ptr(prog))) {
            return;
        }
        self.entries.push(LedgerEntry {
            name: prog.name.clone(),
            prog_type: prog.prog_type,
            insns: prog.op_count(),
            max_cost: prog.info.max_cost,
            jitted: prog.is_jitted(),
            inline_stats: prog.jit_inline_stats(),
            cell: prog.stats_cell(),
            prog: Arc::downgrade(prog),
        });
        if self.entries.len() > LEDGER_CAP {
            self.compact();
        }
    }

    /// Fold every dead entry into the per-hook retired aggregate.
    pub(crate) fn compact(&mut self) {
        let (retired_run, retired_installs) = (&mut self.retired_run, &mut self.retired_installs);
        self.entries.retain(|e| {
            if e.prog.upgrade().is_some() {
                return true;
            }
            let i = hook_idx(e.prog_type);
            if let Some(cell) = &e.cell {
                retired_run[i].absorb(&cell.aggregate());
            }
            retired_installs[i] += 1;
            false
        });
    }

    /// Total run stats attributed to hook `pt`: live + dead tracked
    /// entries plus the compacted aggregate — the left-hand side of
    /// the conservation invariant.
    pub(crate) fn hook_run_stats(&self, pt: ProgType) -> RunStats {
        let mut total = self.retired_run[hook_idx(pt)];
        for e in self.entries.iter().filter(|e| e.prog_type == pt) {
            if let Some(cell) = &e.cell {
                total.absorb(&cell.aggregate());
            }
        }
        total
    }
}

/// One row of [`HostSnapshot::programs`]: a program the host installed,
/// its load-time facts, and its run stats so far.
#[derive(Clone, Debug)]
pub struct ProgramRow {
    /// program name from the object
    pub name: String,
    /// hook type it was verified for
    pub prog_type: ProgType,
    /// pre-decoded instruction count
    pub insns: usize,
    /// certified worst-case cost (the admission-gate input)
    pub max_cost: u64,
    /// whether [`LoadedProgram::run`] dispatches to native code
    pub jitted: bool,
    /// still reachable from a hook slot / prog array / in-flight run
    pub live: bool,
    /// per-site JIT codegen decisions (`None` when interpreted)
    pub inline_stats: Option<JitInlineStats>,
    /// aggregated run stats (all-zero when stats were off at load)
    pub run: RunStats,
}

/// Ring-buffer counters for one ringbuf map (conservation:
/// `emitted == drained + discarded + still-unconsumed`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RingStats {
    /// successfully reserved records
    pub emitted: u64,
    /// records delivered to drain callbacks
    pub drained: u64,
    /// failed reservations (ring full / bad size)
    pub dropped: u64,
    /// producer-discarded records skipped by the consumer
    pub discarded: u64,
    /// deepest unconsumed backlog in bytes ever observed
    pub hiwater_bytes: u64,
}

/// One row of [`HostSnapshot::maps`]: a shared map and its pressure.
#[derive(Clone, Debug)]
pub struct MapRow {
    /// declared map name
    pub name: String,
    /// map kind
    pub kind: MapKind,
    /// registry-assigned live id
    pub id: u32,
    /// live entries ([`crate::bpf::Map::len`] semantics per kind)
    pub entries: usize,
    /// declared capacity
    pub max_entries: u32,
    /// operation counters (always on)
    pub pressure: MapPressureStats,
    /// ringbuf counters (`None` for non-ringbuf maps)
    pub ring: Option<RingStats>,
}

/// One row of [`HostSnapshot::hooks`]: a hook slot's lifecycle state.
#[derive(Clone, Debug)]
pub struct HookRow {
    /// which hook
    pub hook: ProgType,
    /// name of the currently installed policy, if any
    pub active: Option<String>,
    /// total hook-slot swaps
    pub swaps: u64,
    /// latency of the most recent swap (ns)
    pub last_swap_ns: u64,
    /// retired-but-unreclaimed program versions in the slot
    pub retired: usize,
    /// installs compacted out of the ledger
    pub compacted_installs: u64,
    /// run stats folded out of compacted installs
    pub compacted_run: RunStats,
    /// total run stats attributed to this hook (live + retired) — the
    /// conservation-invariant sum
    pub total_run: RunStats,
}

/// One reload-journal entry: a hook-slot swap with its full load-phase
/// timing decomposition.
#[derive(Clone, Debug)]
pub struct JournalEntry {
    /// swap epoch (the hook's swap counter after this swap)
    pub epoch: u64,
    /// which hook swapped
    pub hook: ProgType,
    /// previously active policy (`None` for the first install)
    pub old: Option<String>,
    /// newly installed policy
    pub new: String,
    /// verifier time for the new program (ns)
    pub verify_ns: u64,
    /// post-verification analysis time (cost gate + rewrite, ns)
    pub analyze_ns: u64,
    /// pre-decode + JIT time (ns)
    pub compile_ns: u64,
    /// pointer-swap CAS latency (ns)
    pub swap_ns: u64,
}

impl JournalEntry {
    /// Full reload cost of this swap: verify + analyze + compile +
    /// swap — the same decomposition as
    /// [`crate::host::LoadReport::total_ns`].
    pub fn total_ns(&self) -> u64 {
        self.verify_ns + self.analyze_ns + self.compile_ns + self.swap_ns
    }
}

/// Everything `ncclbpf stats` / `top` shows: the host's installed
/// programs, shared maps, hook slots, recent reloads, and event
/// counters, in one value.
#[derive(Clone, Debug)]
pub struct HostSnapshot {
    /// every install still tracked by the ledger (live and retired)
    pub programs: Vec<ProgramRow>,
    /// every map in the host's registry, sorted by id
    pub maps: Vec<MapRow>,
    /// the three hook slots in tuner/profiler/net order
    pub hooks: Vec<HookRow>,
    /// the most recent hook-slot swaps, oldest first
    pub journal: Vec<JournalEntry>,
    /// tuner decisions executed
    pub decisions: u64,
    /// profiler events executed
    pub prof_events: u64,
    /// net hook invocations
    pub net_events: u64,
    /// policies that wrote semantically invalid outputs
    pub invalid_outputs: u64,
    /// whether per-program run stats were enabled on this host's
    /// load options when the snapshot was taken
    pub stats_enabled: bool,
}

impl HostSnapshot {
    /// The hook row for `pt` (the snapshot always carries all three).
    pub fn hook(&self, pt: ProgType) -> &HookRow {
        &self.hooks[hook_idx(pt)]
    }

    /// Sum of `run_cnt` attributed to hook `pt` across live, retired,
    /// and compacted programs — compare against the host's decision
    /// counter for the conservation invariant.
    pub fn hook_run_cnt(&self, pt: ProgType) -> u64 {
        self.hook(pt).total_run.run_cnt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpf::{CtxLayouts, LoadOptions, MapRegistry};

    fn tuner(reg: &MapRegistry, name: &str) -> Arc<LoadedProgram> {
        let src = format!("prog tuner {}\n  mov64 r0, 0\n  exit\n", name);
        let obj = crate::bpf::asm::assemble(&src).unwrap();
        let layouts = CtxLayouts::default();
        let opts = LoadOptions::new().stats(Some(true));
        Arc::new(crate::bpf::load(&obj, reg, &layouts, &opts).unwrap().programs.remove(0))
    }

    #[test]
    fn ledger_compaction_preserves_run_counts() {
        let reg = MapRegistry::new();
        let mut ledger = InstallLedger::default();
        let mut expect = 0u64;
        for i in 0..(LEDGER_CAP + 10) {
            let p = tuner(&reg, &format!("p{}", i));
            p.run(std::ptr::null_mut());
            expect += 1;
            ledger.record(&p);
            // p drops here: the entry's Weak dies, the cell survives
        }
        assert!(ledger.entries.len() <= LEDGER_CAP, "compaction bounds the ledger");
        assert_eq!(ledger.hook_run_stats(ProgType::Tuner).run_cnt, expect);
        assert_eq!(ledger.hook_run_stats(ProgType::Profiler).run_cnt, 0);
        assert!(ledger.retired_installs[hook_idx(ProgType::Tuner)] > 0);
    }

    #[test]
    fn ledger_refuses_duplicate_installs() {
        let reg = MapRegistry::new();
        let mut ledger = InstallLedger::default();
        let p = tuner(&reg, "p");
        ledger.record(&p);
        ledger.record(&p);
        assert_eq!(ledger.entries.len(), 1, "same Arc must not double-count");
        p.run(std::ptr::null_mut());
        assert_eq!(ledger.hook_run_stats(ProgType::Tuner).run_cnt, 1);
    }
}
