//! Loading policies from the `policies/` directory (restricted C via
//! bpfc, or `.s` via the assembler) — the operator-facing authoring
//! path used by the CLI, benches and the §5.2 safety suite.

use crate::bpf::Object;
use std::path::{Path, PathBuf};

/// Repo-relative policies directory.
pub fn policies_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("policies")
}

/// Compile/assemble one policy source file into an object.
pub fn build_policy(path: &Path) -> Result<Object, String> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {}", path.display(), e))?;
    match path.extension().and_then(|e| e.to_str()) {
        Some("c") => crate::bpfc::compile(&src),
        Some("s") | Some("asm") => {
            crate::bpf::asm::assemble(&src).map_err(|e| e.to_string())
        }
        other => Err(format!("unknown policy extension {:?} for {}", other, path.display())),
    }
}

/// Build a named policy from `policies/NAME.c` (or `.s`).
pub fn build_named(name: &str) -> Result<Object, String> {
    let dir = policies_dir();
    for ext in ["c", "s"] {
        let p = dir.join(format!("{}.{}", name, ext));
        if p.exists() {
            return build_policy(&p);
        }
    }
    Err(format!("no policy named '{}' in {}", name, dir.display()))
}

/// The safe policies of the §5.2 suite (all in Table 1 / §5.3), plus
/// the composable tail-call chain exemplar (§5.4 shape), the
/// cost-corpus exemplar sized just under the Tuner install budget
/// (the certifier-headroom probe), and the two contended-shared-state
/// exemplars built on BPF_ATOMIC read-modify-writes over plain Array
/// maps (`__sync_*` intrinsics; exact conservation without per-cpu
/// slots).
pub const SAFE_POLICIES: [&str; 11] = [
    "noop",
    "static_ring",
    "size_aware",
    "adaptive_channels",
    "latency_aware",
    "slo_enforcer",
    "nvlink_ring_mid_v2",
    "chain_dispatch",
    "cost_tight",
    "shared_counters",
    "size_histogram",
];

/// The unsafe programs, one per bug class: the paper's seven (§5.2),
/// the three ringbuf reference-tracking classes, the three call-graph
/// classes (recursion, cross-frame stack overflow, clobbered-register
/// misuse), the three atomic classes (ctx-pointer RMW, misalignment,
/// out-of-bounds RMW window), and the net-ctx bounds probe (a read one
/// word past the 32-byte `net` context).
pub const UNSAFE_POLICIES: [(&str, &str); 17] = [
    ("null_deref", "map_value_or_null"),
    ("oob_access", "out of bounds"),
    ("illegal_helper", "illegal helper"),
    ("stack_overflow", "stack"),
    ("unbounded_loop", "unbounded loop"),
    ("input_write", "read-only"),
    ("div_zero", "division by zero"),
    ("ringbuf_leak", "unreleased"),
    ("ringbuf_use_after_submit", "use after release"),
    ("ringbuf_oob", "reserved size"),
    ("call_recursion", "recursive"),
    ("call_stack_overflow", "combined stack"),
    ("call_r6_clobber", "r1-r5"),
    ("atomic_on_ctx", "atomic op on ctx"),
    ("atomic_misaligned", "misaligned atomic"),
    ("atomic_oob", "out of bounds"),
    ("net_ctx_oob", "invalid ctx read"),
];

/// The `net` policy corpus: verified policies that run on the
/// transport send/recv datapath ([`crate::cc::net::PolicyTransport`]).
/// Kept outside [`SAFE_POLICIES`] so Table 1 keeps measuring exactly
/// the tuner corpus; `ncclbpf safety` and the multinode bench cover
/// them.
pub const NET_POLICIES: [(&str, &str); 2] = [
    ("net_count", "per-direction transfer counters over one shared map"),
    (
        "rail_selector",
        "steers transfers to a rail by message size, clamped to ctx->rails, \
         with per-rail pick counters",
    ),
];

/// The verification-cost stress corpus: safe policies sized so that
/// exhaustive path enumeration exhausts the verifier's complexity
/// budget while state-equivalence pruning verifies them with large
/// headroom (asserted both ways by `tests/verifier_pruning.rs`). They
/// live outside [`SAFE_POLICIES`] so Table 1 keeps measuring exactly
/// the paper's corpus; `ncclbpf safety` and `BENCH_verifier.json`
/// cover them whenever pruning is enabled.
pub const STRESS_POLICIES: [(&str, &str); 2] = [
    ("stress_ladder64", "64-arm size ladder joining into a bounded refinement loop"),
    ("stress_channel_scorer", "32-lap channel scorer with a data-dependent branch per lap"),
];

/// The over-budget cost corpus: policies the verifier *accepts*
/// (bounded, memory-safe) whose certified worst-case cost exceeds the
/// per-hook install budget, so the host's cost-certifier gate must
/// reject them at load. They are deliberately not in
/// [`UNSAFE_POLICIES`]: that corpus asserts verifier rejections, and
/// these programs verify clean — only the budget gate fires.
pub const OVER_BUDGET_POLICIES: [&str; 1] = ["cost_blowout"];

/// Build an unsafe-suite program from `policies/unsafe/`.
pub fn build_unsafe(name: &str) -> Result<Object, String> {
    let dir = policies_dir().join("unsafe");
    for ext in ["c", "s"] {
        let p = dir.join(format!("{}.{}", name, ext));
        if p.exists() {
            return build_policy(&p);
        }
    }
    Err(format!("no unsafe policy named '{}'", name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::host::NcclBpfHost;

    #[test]
    fn all_safe_policies_build_and_install() {
        let host = NcclBpfHost::new();
        for name in SAFE_POLICIES {
            let obj = build_named(name).unwrap_or_else(|e| panic!("{}: {}", name, e));
            host.install_object(&obj)
                .unwrap_or_else(|e| panic!("{} must verify: {}", name, e));
        }
        // profiler + net companions (latency_events is the ringbuf
        // producer behind `ncclbpf trace` and the closed-loop driver)
        for name in ["record_latency", "net_count", "bad_channels", "latency_events"] {
            let obj = build_named(name).unwrap();
            host.install_object(&obj).unwrap();
        }
    }

    /// The net corpus builds, verifies, and behaves: `rail_selector`
    /// returns a rail index bounded by `ctx->rails` and its per-rail
    /// pick counters conserve.
    #[test]
    fn net_policies_build_and_rail_selector_steers_by_size() {
        use crate::cc::NetOp;
        let host = NcclBpfHost::new();
        for (name, _) in NET_POLICIES {
            let obj = build_named(name).unwrap_or_else(|e| panic!("{}: {}", name, e));
            host.install_object(&obj)
                .unwrap_or_else(|e| panic!("{} must verify: {}", name, e));
        }
        // rail_selector is installed last and owns the net slot now
        let op = |bytes: u64, rails: u32| NetOp {
            is_send: true,
            bytes,
            peer: 1,
            rail: 0,
            rails,
            node: 0,
        };
        // size tiers: <64K -> 0, <1M -> 1, <16M -> 2, else 3
        assert_eq!(host.net_handle_op(7, &op(4 << 10, 4)), Some(0));
        assert_eq!(host.net_handle_op(7, &op(256 << 10, 4)), Some(1));
        assert_eq!(host.net_handle_op(7, &op(4 << 20, 4)), Some(2));
        assert_eq!(host.net_handle_op(7, &op(64 << 20, 4)), Some(3));
        // clamp: a 2-rail node folds the upper tiers onto rail 0
        assert_eq!(host.net_handle_op(7, &op(64 << 20, 2)), Some(0));
        let m = host.map("rail_pick").expect("rail_pick map");
        let total: u64 = (0u32..4).filter_map(|k| m.read_u64(k)).sum();
        assert_eq!(total, 5, "every decision lands one pick counter");
    }

    /// The contended-shared-state exemplars conserve exactly: every
    /// decision lands one BPF_ATOMIC increment in plain (non-per-cpu)
    /// map memory, so a single host-side read equals the op count.
    #[test]
    fn shared_counter_policies_conserve_exactly() {
        use crate::cc::plugin::{CollInfoArgs, CostTable};
        use crate::cc::{CollType, MAX_CHANNELS};
        let args = |nbytes: usize| CollInfoArgs {
            coll: CollType::AllReduce,
            nbytes,
            nranks: 8,
            comm_id: 1,
            max_channels: MAX_CHANNELS,
        };
        let host = NcclBpfHost::new();
        host.install_object(&build_named("shared_counters").unwrap()).unwrap();
        let mut bytes = 0u64;
        for i in 0..100usize {
            let mut cost = CostTable::all_sentinel();
            let mut ch = 0u32;
            host.tuner_decide(&args(4096 + i), &mut cost, &mut ch);
            bytes += 4096 + i as u64;
        }
        let m = host.map("shared_stats_map").expect("shared_stats_map");
        let v = m.read_value(&0u32.to_le_bytes()).unwrap();
        assert_eq!(u64::from_le_bytes(v[..8].try_into().unwrap()), 100, "decisions");
        assert_eq!(u64::from_le_bytes(v[8..16].try_into().unwrap()), bytes, "bytes");

        host.install_object(&build_named("size_histogram").unwrap()).unwrap();
        for i in 0..64usize {
            let mut cost = CostTable::all_sentinel();
            let mut ch = 0u32;
            host.tuner_decide(&args((4 << 10) << (i % 12)), &mut cost, &mut ch);
        }
        let m = host.map("size_hist").expect("size_hist");
        let hits: u64 = (0u32..8)
            .map(|k| {
                let v = m.read_value(&k.to_le_bytes()).unwrap();
                u64::from_le_bytes(v[..8].try_into().unwrap())
            })
            .sum();
        assert_eq!(hits, 64, "sum(bucket.hits) == decisions");
        // the cmpxchg latch recorded the first non-zero bucket exactly once
        let head = m.read_value(&0u32.to_le_bytes()).unwrap();
        let first = u64::from_le_bytes(head[16..24].try_into().unwrap());
        assert!((1..8).contains(&first), "latched bucket index, got {}", first);
    }

    #[test]
    fn stress_policies_build_and_install_with_pruning() {
        let host = NcclBpfHost::new();
        for (name, _shape) in STRESS_POLICIES {
            let obj = build_named(name).unwrap_or_else(|e| panic!("{}: {}", name, e));
            let rep = host
                .install_object(&obj)
                .unwrap_or_else(|e| panic!("{} must verify with pruning on: {}", name, e));
            let (_, st) = &rep.prog_stats[0];
            assert!(st.states_pruned > 0, "{}: pruning must actually fire", name);
        }
    }

    #[test]
    fn over_budget_policies_verify_but_fail_the_cost_gate() {
        let host = NcclBpfHost::new();
        for name in OVER_BUDGET_POLICIES {
            let obj = build_named(name).unwrap_or_else(|e| panic!("{}: {}", name, e));
            let err = host
                .install_object(&obj)
                .expect_err(&format!("{} must exceed the cost budget", name));
            let msg = err.to_string();
            assert!(msg.contains("cost budget"), "{}: expected cost diagnostic, got: {}", name, msg);
        }
        assert!(host.active_name(crate::bpf::ProgType::Tuner).is_none());
    }

    #[test]
    fn all_unsafe_policies_rejected_with_expected_class() {
        let host = NcclBpfHost::new();
        for (name, needle) in UNSAFE_POLICIES {
            let obj = build_unsafe(name).unwrap_or_else(|e| panic!("{}: {}", name, e));
            let err = host
                .install_object(&obj)
                .expect_err(&format!("{} must be rejected", name));
            let msg = err.to_string();
            assert!(
                msg.to_lowercase().contains(needle),
                "{}: expected '{}' in error, got: {}",
                name,
                needle,
                msg
            );
        }
        // nothing was installed
        assert!(host.active_name(crate::bpf::ProgType::Tuner).is_none());
    }
}
