//! Concurrent multi-communicator traffic engine.
//!
//! The paper's headline numbers (Table 1, §5.2) are single-communicator,
//! single-thread measurements; production deployments run many
//! communicators per process. This module makes that shape a
//! first-class, *checked* scenario: N [`Communicator`]s spread over N OS
//! threads share one [`NcclBpfHost`] (one [`crate::bpf::MapRegistry`],
//! one set of reload slots), a workload generator drives mixed
//! collectives with per-communicator seeds, and an optional reloader
//! thread hot-swaps the tuner policy mid-traffic.
//!
//! What is shared vs per-communicator:
//! - **shared**: the host (program slots, maps, counters) — every hook
//!   dispatch is `&self` and lock-free.
//! - **per-communicator**: the modeled clock, sequence numbers, warmup
//!   state, jitter RNG (all inside [`Communicator`]) and the rank
//!   buffers (owned by the worker thread).
//!
//! Invariants checked on every run (violations are returned, not
//! asserted, so the CLI can exit non-zero):
//! 1. **no lost decisions** — the host's `decisions` counter equals the
//!    number of collectives issued (every op consults the tuner).
//! 2. **no torn policy reads** — the two tuner variants write
//!    recognizably distinct (algorithm, protocol, channels) tuples;
//!    every decision must observe exactly one variant's tuple, never a
//!    mix of both.
//! 3. **map totals consistent with per-thread counts** — the tuner and
//!    profiler policies each bump a per-cpu counter map on the worker's
//!    pinned slot; the host-side all-slot aggregation
//!    ([`crate::bpf::Map::read_u64_all`]) must equal the op total.
//! 4. **no unbounded retirement** — after the reload storm quiesces,
//!    the retired-program lists reclaim down to zero.
//! 5. **run-stat conservation** (hosts with per-program stats enabled)
//!    — the install ledger's per-hook `run_cnt` totals equal the
//!    host's dispatch counters even across the reload storm, because
//!    the ledger keeps each retired program's stat cell alive.
//! 6. **shared-counter conservation** — both tuner variants also bump
//!    one *plain* Array element with a BPF_ATOMIC add; the single
//!    host-side read must equal the op total at any thread count (no
//!    per-cpu slot caveat) even across the reload storm, because the
//!    increments are lock RMWs on memory shared by every worker.

use crate::bpf::maps::pin_thread_cpu_slot;
use crate::bpf::maps::NCPU;
use crate::cc::net::{
    FaultPlan, FaultyTransport, NetError, NetOp, NetTransport, PolicyTransport,
    RdmaModelTransport,
};
use crate::cc::{Algo, ClusterTopology, CollType, Communicator, DataMode, Proto, Topology};
use crate::host::{BpfProfilerPlugin, BpfTunerPlugin, NcclBpfHost};
use crate::util::{percentile, Rng};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The two tuner variants the reloader alternates between. Each bumps
/// `traffic_hits[0]` on its per-cpu slot, bumps the *shared*
/// `shared_hits[0]` counter with a BPF_ATOMIC add (one plain Array
/// element contended by every worker thread), and writes a marker
/// output tuple; the tuples share no field values, so a decision that
/// mixes them is a torn read.
const TUNER_VARIANT_A: &str = r#"
map traffic_hits percpu key=4 value=8 entries=1
map shared_hits array key=4 value=8 entries=1

prog tuner traffic_a
  mov64 r6, r1
  stw   [r10-4], 0
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, traffic_hits
  call  bpf_map_lookup_elem
  jeq   r0, 0, shared
  ldxdw r3, [r0+0]
  add64 r3, 1
  stxdw [r0+0], r3
shared:
  stw   [r10-4], 0
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, shared_hits
  call  bpf_map_lookup_elem
  jeq   r0, 0, out
  mov64 r3, 1
  lock add64 [r0+0], r3
out:
  stw   [r6+32], 0        ; algorithm = RING
  stw   [r6+36], 2        ; protocol  = SIMPLE
  stw   [r6+40], 7        ; n_channels
  mov64 r0, 0
  exit
"#;

const TUNER_VARIANT_B: &str = r#"
map traffic_hits percpu key=4 value=8 entries=1
map shared_hits array key=4 value=8 entries=1

prog tuner traffic_b
  mov64 r6, r1
  stw   [r10-4], 0
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, traffic_hits
  call  bpf_map_lookup_elem
  jeq   r0, 0, shared
  ldxdw r3, [r0+0]
  add64 r3, 1
  stxdw [r0+0], r3
shared:
  stw   [r10-4], 0
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, shared_hits
  call  bpf_map_lookup_elem
  jeq   r0, 0, out
  mov64 r3, 1
  lock add64 [r0+0], r3
out:
  stw   [r6+32], 1        ; algorithm = TREE
  stw   [r6+36], 0        ; protocol  = LL
  stw   [r6+40], 13       ; n_channels
  mov64 r0, 0
  exit
"#;

/// Profiler policy: one per-cpu counter bump per CollEnd event, plus a
/// 16-byte structured event (latency_ns, seq) pushed into the
/// `traffic_events` ring buffer — the consumer thread drains it live
/// and the run checks `drained + dropped == ops` at the end.
const PROFILER_COUNTER: &str = r#"
map prof_hits percpu key=4 value=8 entries=1
map traffic_events ringbuf entries=262144

prog profiler traffic_prof
  mov64 r6, r1
  stw   [r10-4], 0
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, prof_hits
  call  bpf_map_lookup_elem
  jeq   r0, 0, emit
  ldxdw r3, [r0+0]
  add64 r3, 1
  stxdw [r0+0], r3
emit:
  ldxdw r3, [r6+16]       ; latency_ns
  stxdw [r10-24], r3
  ldxw  r4, [r6+28]       ; seq
  stxdw [r10-16], r4
  ldmap r1, traffic_events
  mov64 r2, r10
  add64 r2, -24
  mov64 r3, 16
  mov64 r4, 0
  call  bpf_ringbuf_output
out:
  mov64 r0, 0
  exit
"#;

/// The two net-policy variants the reloader alternates between when the
/// run is multi-node. Both bump `rail_hits[ctx->rail]` on one *plain*
/// Array with a BPF_ATOMIC add — the per-rail counters conserve across
/// install swaps because the map outlives the programs — and differ
/// only in their r0 verdict, so either variant satisfies the per-rail
/// conservation invariant mid-storm.
const NET_RAIL_A: &str = r#"
map rail_hits array key=4 value=8 entries=16

prog net rail_count_a
  mov64 r6, r1
  ldxw  r7, [r6+20]       ; rail
  jge   r7, 16, out
  stxw  [r10-4], r7
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, rail_hits
  call  bpf_map_lookup_elem
  jeq   r0, 0, out
  mov64 r3, 1
  lock add64 [r0+0], r3
out:
  mov64 r0, 0
  exit
"#;

const NET_RAIL_B: &str = r#"
map rail_hits array key=4 value=8 entries=16

prog net rail_count_b
  mov64 r6, r1
  ldxw  r7, [r6+20]       ; rail
  jge   r7, 16, out
  stxw  [r10-4], r7
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, rail_hits
  call  bpf_map_lookup_elem
  jeq   r0, 0, out
  mov64 r3, 1
  lock add64 [r0+0], r3
out:
  mov64 r0, 1
  exit
"#;

/// Bytes per simulated cross-node transfer (fixed so the modeled rail
/// loopback can be drained with one reusable buffer).
const NET_SHARD: usize = 4096;

/// Rails per node in cluster scenarios.
const NET_RAILS: usize = 4;

/// Knobs for one traffic run.
#[derive(Clone, Copy, Debug)]
pub struct TrafficOpts {
    /// total communicators (spread round-robin over the threads)
    pub comms: usize,
    /// OS threads (clamped to `comms`; per-cpu-exact checks need ≤ 16)
    pub threads: usize,
    /// collective ops issued per communicator
    pub ops_per_comm: usize,
    /// hot-reload the tuner every this many ms (None: no reloads)
    pub reload_every_ms: Option<u64>,
    /// master seed; per-communicator generators derive from it
    pub seed: u64,
    /// ranks per communicator
    pub ranks: usize,
    /// simulated nodes (1 = single-node, no net datapath; > 1 adds
    /// `ranks` GPUs per node and a rail-aware net stage per op)
    pub nodes: usize,
    /// inject link flaps / stragglers / degraded epochs on the rails
    pub fault: bool,
}

impl Default for TrafficOpts {
    fn default() -> Self {
        TrafficOpts {
            comms: 4,
            threads: 4,
            ops_per_comm: 10_000,
            reload_every_ms: Some(50),
            seed: 0x7a_ff1c,
            ranks: 4,
            nodes: 1,
            fault: false,
        }
    }
}

/// Per-worker-thread statistics.
#[derive(Clone, Debug, Default)]
pub struct ThreadStats {
    /// worker index
    pub thread: usize,
    /// communicators this worker drove
    pub comms: usize,
    /// collective ops issued
    pub ops: u64,
    /// decisions observing variant A's output tuple
    pub variant_a: u64,
    /// decisions observing variant B's output tuple
    pub variant_b: u64,
    /// decisions observing a mixed tuple (must stay 0)
    pub torn: u64,
    /// logical payload bytes moved
    pub bytes_moved: u64,
    /// per-decision host overhead samples (ns)
    pub decision_ns: Vec<f64>,
    /// net policy decisions issued on the rail datapath
    pub net_ops: u64,
    /// link flaps observed (isend returned LinkDown)
    pub net_flaps: u64,
    /// transfers recovered by retrying on another rail
    pub net_retries: u64,
    /// transfers that exhausted every rail (must stay 0)
    pub net_lost: u64,
    /// modeled rail time including injected straggler delay (ns)
    pub net_modeled_ns: u64,
}

/// Outcome of one traffic run.
#[derive(Clone, Debug, Default)]
pub struct TrafficReport {
    /// worker threads used
    pub threads: usize,
    /// communicators driven
    pub comms: usize,
    /// total collective ops across all workers
    pub total_ops: u64,
    /// tuner decisions executed
    pub total_decisions: u64,
    /// tuner hot-reloads performed mid-traffic
    pub reloads: u64,
    /// wall-clock duration of the run
    pub wall_ns: u64,
    /// decision throughput over the whole run
    pub decisions_per_sec: f64,
    /// median per-decision latency (ns)
    pub p50_decision_ns: f64,
    /// 99th-percentile per-decision latency (ns)
    pub p99_decision_ns: f64,
    /// mean per-decision latency (ns)
    pub mean_decision_ns: f64,
    /// all-slot sum of the tuner counter map
    pub tuner_map_hits: u64,
    /// single-read value of the shared BPF_ATOMIC counter (plain Array
    /// element contended by every worker)
    pub shared_map_hits: u64,
    /// all-slot sum of the profiler counter map
    pub prof_map_hits: u64,
    /// structured events drained from the `traffic_events` ring this run
    pub ring_drained: u64,
    /// producer-side ring drops this run (failed reservations)
    pub ring_dropped: u64,
    /// simulated nodes (1 = no net datapath)
    pub nodes: usize,
    /// net policy decisions issued on the rail datapath
    pub net_decisions: u64,
    /// net program dispatches the host counted
    pub net_events: u64,
    /// sum of the `rail_hits` per-rail BPF_ATOMIC counters
    pub rail_map_hits: u64,
    /// per-rail breakdown of `rail_hits`
    pub rail_hits: Vec<u64>,
    /// link flaps injected/observed across all workers
    pub net_flaps: u64,
    /// transfers recovered on another rail
    pub net_retries: u64,
    /// transfers lost after exhausting every rail (must stay 0)
    pub net_lost: u64,
    /// modeled rail time including straggler delay (ns)
    pub net_modeled_ns: u64,
    /// invariant violations (empty == clean run)
    pub violations: Vec<String>,
    /// per-worker breakdown
    pub per_thread: Vec<ThreadStats>,
}

/// Drive `opts.comms` communicators over `opts.threads` threads against
/// one shared host, with the reloader swapping tuner variants
/// mid-traffic, and check the engine invariants.
pub fn run_traffic(opts: &TrafficOpts) -> TrafficReport {
    let host = Arc::new(NcclBpfHost::new());
    install_traffic_policies(&host).expect("traffic policies must verify");
    if opts.nodes > 1 {
        host.install_asm(NET_RAIL_A).expect("net rail policy must verify");
    }
    run_traffic_on(host, opts)
}

/// Install the traffic tuner (variant A) + ringbuf profiler pair on
/// `host` — the precondition [`run_traffic_on`] expects. Exposed for
/// callers that pre-configure the host (e.g. `ncclbpf top` runs the
/// engine against a host with per-program run stats enabled).
pub fn install_traffic_policies(host: &NcclBpfHost) -> Result<(), crate::bpf::LoadError> {
    host.install_asm(TUNER_VARIANT_A)?;
    host.install_asm(PROFILER_COUNTER)?;
    Ok(())
}

/// Same as [`run_traffic`] but against a caller-provided host that
/// already has the traffic tuner + profiler installed — for callers
/// that want to pre-condition the host (e.g. the reload-storm
/// regression test) or inspect it after the run. Counters are read as
/// deltas, so a host that has already served traffic is fine.
pub fn run_traffic_on(host: Arc<NcclBpfHost>, opts: &TrafficOpts) -> TrafficReport {
    let threads = opts.threads.clamp(1, opts.comms.max(1));
    let comms = opts.comms.max(1);
    let ops_per_comm = opts.ops_per_comm.max(1);
    let nodes = opts.nodes.max(1);
    if nodes > 1 && host.map("rail_hits").is_none() {
        host.install_asm(NET_RAIL_A).expect("net rail policy must verify");
    }

    let decisions_before = host.decisions.load(Ordering::Relaxed);
    let prof_before = host.prof_events.load(Ordering::Relaxed);
    let net_events_before = host.net_events.load(Ordering::Relaxed);
    let invalid_before = host.invalid_outputs.load(Ordering::Relaxed);
    let tuner_hits_before =
        host.map("traffic_hits").and_then(|m| m.read_u64_all(0)).unwrap_or(0);
    let shared_hits_before = host.map("shared_hits").and_then(|m| m.read_u64(0)).unwrap_or(0);
    let prof_hits_before = host.map("prof_hits").and_then(|m| m.read_u64_all(0)).unwrap_or(0);
    let rail_hits_before: Vec<u64> = (0..16u32)
        .map(|i| host.map("rail_hits").and_then(|m| m.read_u64(i)).unwrap_or(0))
        .collect();

    let stop = Arc::new(AtomicBool::new(false));
    let reloads = Arc::new(AtomicU64::new(0));

    // ring consumer: drain any leftovers from a previous run on this
    // host first, then count only this run's records (drop/discard
    // counters via delta)
    let ring_map = host.map("traffic_events");
    let ring_dropped_before = ring_map.as_ref().map(|m| m.ringbuf_dropped()).unwrap_or(0);
    let ring_discarded_before = ring_map.as_ref().map(|m| m.ringbuf_discarded()).unwrap_or(0);
    if let Some(m) = ring_map.as_ref() {
        m.ringbuf_drain(&mut |_| {});
    }
    let consumer = ring_map.clone().map(|m| {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut c =
                crate::host::ringbuf::RingConsumer::new(m).expect("traffic ring map kind");
            let mut torn_records = 0u64;
            let drained = c.drain_until(&stop, |b| {
                if b.len() != 16 {
                    torn_records += 1;
                }
            });
            (drained, torn_records)
        })
    });

    // reloader: alternate tuner (and, multi-node, net) variants until
    // the workers finish — the reload storm overlaps the fault epochs
    let reloader = opts.reload_every_ms.map(|every_ms| {
        let host = host.clone();
        let stop = stop.clone();
        let reloads = reloads.clone();
        let swap_net = nodes > 1;
        std::thread::spawn(move || {
            let mut flip = false;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(every_ms.max(1)));
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let src = if flip { TUNER_VARIANT_A } else { TUNER_VARIANT_B };
                host.install_asm(src).expect("traffic reload must verify");
                if swap_net {
                    let net_src = if flip { NET_RAIL_A } else { NET_RAIL_B };
                    host.install_asm(net_src).expect("net reload must verify");
                }
                flip = !flip;
                reloads.fetch_add(1, Ordering::Relaxed);
            }
        })
    });

    let t0 = Instant::now();
    let mut workers = Vec::with_capacity(threads);
    for t in 0..threads {
        let host = host.clone();
        let opts = *opts;
        // communicators t, t+threads, t+2*threads, ... belong to worker t
        let my_comms = (t..comms).step_by(threads).count();
        workers.push(std::thread::spawn(move || {
            worker_loop(t, my_comms, ops_per_comm, &host, &opts)
        }));
    }
    let per_thread: Vec<ThreadStats> =
        workers.into_iter().map(|h| h.join().expect("traffic worker panicked")).collect();
    let wall_ns = t0.elapsed().as_nanos() as u64;
    stop.store(true, Ordering::Release);
    if let Some(h) = reloader {
        h.join().expect("reloader panicked");
    }
    let (ring_drained, ring_torn) = consumer
        .map(|h| h.join().expect("ring consumer panicked"))
        .unwrap_or((0, 0));
    let ring_dropped = ring_map
        .as_ref()
        .map(|m| m.ringbuf_dropped())
        .unwrap_or(0)
        .saturating_sub(ring_dropped_before);
    let ring_discarded = ring_map
        .as_ref()
        .map(|m| m.ringbuf_discarded())
        .unwrap_or(0)
        .saturating_sub(ring_discarded_before);
    host.reclaim_retired();

    // -- aggregate + invariant checks ----------------------------------------
    let total_ops: u64 = per_thread.iter().map(|s| s.ops).sum();
    let total_decisions = host.decisions.load(Ordering::Relaxed) - decisions_before;
    let prof_events = host.prof_events.load(Ordering::Relaxed) - prof_before;
    let net_events = host.net_events.load(Ordering::Relaxed) - net_events_before;
    let net_decisions: u64 = per_thread.iter().map(|s| s.net_ops).sum();
    let net_flaps: u64 = per_thread.iter().map(|s| s.net_flaps).sum();
    let net_retries: u64 = per_thread.iter().map(|s| s.net_retries).sum();
    let net_lost: u64 = per_thread.iter().map(|s| s.net_lost).sum();
    let net_modeled_ns: u64 = per_thread.iter().map(|s| s.net_modeled_ns).sum();
    let rail_hits: Vec<u64> = (0..16u32)
        .map(|i| {
            host.map("rail_hits")
                .and_then(|m| m.read_u64(i))
                .unwrap_or(0)
                .wrapping_sub(rail_hits_before[i as usize])
        })
        .collect();
    let rail_map_hits: u64 = rail_hits.iter().sum();
    let tuner_map_hits = host
        .map("traffic_hits")
        .and_then(|m| m.read_u64_all(0))
        .unwrap_or(0)
        .wrapping_sub(tuner_hits_before);
    let shared_map_hits = host
        .map("shared_hits")
        .and_then(|m| m.read_u64(0))
        .unwrap_or(0)
        .wrapping_sub(shared_hits_before);
    let prof_map_hits = host
        .map("prof_hits")
        .and_then(|m| m.read_u64_all(0))
        .unwrap_or(0)
        .wrapping_sub(prof_hits_before);

    let mut violations = Vec::new();
    if total_decisions != total_ops {
        violations.push(format!(
            "lost decisions: {} ops issued but host counted {}",
            total_ops, total_decisions
        ));
    }
    if prof_events != total_ops {
        violations.push(format!(
            "lost profiler events: {} ops issued but host counted {}",
            total_ops, prof_events
        ));
    }
    let torn: u64 = per_thread.iter().map(|s| s.torn).sum();
    if torn != 0 {
        violations.push(format!("torn policy reads: {}", torn));
    }
    // per-cpu slot sums are exact only while every worker has its own slot
    if threads <= NCPU {
        if tuner_map_hits != total_ops {
            violations.push(format!(
                "tuner map total {} != per-thread op total {}",
                tuner_map_hits, total_ops
            ));
        }
        if prof_map_hits != total_ops {
            violations.push(format!(
                "profiler map total {} != per-thread op total {}",
                prof_map_hits, total_ops
            ));
        }
    }
    // shared-counter conservation: BPF_ATOMIC adds on one plain Array
    // element are exact at ANY thread count — no per-cpu slot caveat
    if shared_map_hits != total_ops {
        violations.push(format!(
            "shared atomic counter {} != {} ops issued",
            shared_map_hits, total_ops
        ));
    }
    // event-stream conservation: every profiler invocation attempted
    // one ring record, and each was drained, drop-accounted, or
    // (for reserve+discard policies) discard-accounted
    if ring_map.is_some() {
        if ring_drained + ring_dropped + ring_discarded != total_ops {
            violations.push(format!(
                "ring events lost: drained {} + dropped {} + discarded {} != {} ops issued",
                ring_drained, ring_dropped, ring_discarded, total_ops
            ));
        }
        if ring_torn != 0 {
            violations.push(format!("torn ring records: {} with wrong length", ring_torn));
        }
    }
    let snap = host.snapshot();
    let retired: usize = snap.hooks.iter().map(|h| h.retired).sum();
    if retired > 2 {
        violations.push(format!(
            "retired programs not reclaimed after quiescence: tuner={} profiler={} net={}",
            snap.hook(crate::bpf::ProgType::Tuner).retired,
            snap.hook(crate::bpf::ProgType::Profiler).retired,
            snap.hook(crate::bpf::ProgType::Net).retired,
        ));
    }
    // run-stat conservation: with per-program stats enabled, every
    // dispatch is attributed to exactly one program (tail-called chain
    // links are attributed to their initiator), so across the reload
    // storm the ledger total must equal the host's dispatch counters.
    // Whole-host counts, not deltas: the ledger aggregates since host
    // creation.
    if host.stats_enabled() {
        let tuner_runs = snap.hook_run_cnt(crate::bpf::ProgType::Tuner);
        let decisions_now = host.decisions.load(Ordering::Relaxed);
        if tuner_runs != decisions_now {
            violations.push(format!(
                "run-stat conservation broken: sum(tuner run_cnt) {} != {} decisions",
                tuner_runs, decisions_now
            ));
        }
        let prof_runs = snap.hook_run_cnt(crate::bpf::ProgType::Profiler);
        let prof_now = host.prof_events.load(Ordering::Relaxed);
        if prof_runs != prof_now {
            violations.push(format!(
                "run-stat conservation broken: sum(profiler run_cnt) {} != {} events",
                prof_runs, prof_now
            ));
        }
    }
    let invalid = host.invalid_outputs.load(Ordering::Relaxed) - invalid_before;
    if invalid != 0 {
        violations.push(format!("policies produced {} invalid outputs", invalid));
    }
    // multi-node invariants: no net decision lost across failure
    // epochs or the reload storm — every policy consult the workers
    // issued must appear in the host dispatch counter AND in the
    // per-rail BPF_ATOMIC counters, and no transfer may exhaust all
    // rails (flap epochs are staggered, so a retry always lands).
    if nodes > 1 {
        if net_events != net_decisions {
            violations.push(format!(
                "lost net decisions: {} issued but host counted {}",
                net_decisions, net_events
            ));
        }
        if rail_map_hits != net_decisions {
            violations.push(format!(
                "per-rail counters not conserved: sum(rail_hits) {} != {} net decisions",
                rail_map_hits, net_decisions
            ));
        }
        if net_lost != 0 {
            violations.push(format!("{} transfers exhausted every rail", net_lost));
        }
    }

    let mut all_ns: Vec<f64> = Vec::with_capacity(total_ops as usize);
    for s in &per_thread {
        all_ns.extend_from_slice(&s.decision_ns);
    }
    let wall_s = (wall_ns as f64 / 1e9).max(1e-9);
    TrafficReport {
        threads,
        comms,
        total_ops,
        total_decisions,
        reloads: reloads.load(Ordering::Relaxed),
        wall_ns,
        decisions_per_sec: total_ops as f64 / wall_s,
        p50_decision_ns: percentile(&all_ns, 50.0),
        p99_decision_ns: percentile(&all_ns, 99.0),
        mean_decision_ns: all_ns.iter().sum::<f64>() / all_ns.len().max(1) as f64,
        tuner_map_hits,
        shared_map_hits,
        prof_map_hits,
        ring_drained,
        ring_dropped,
        nodes,
        net_decisions,
        net_events,
        rail_map_hits,
        rail_hits,
        net_flaps,
        net_retries,
        net_lost,
        net_modeled_ns,
        violations,
        per_thread,
    }
}

/// One worker: own communicators, own buffers, shared host.
fn worker_loop(
    thread_idx: usize,
    n_comms: usize,
    ops_per_comm: usize,
    host: &Arc<NcclBpfHost>,
    opts: &TrafficOpts,
) -> ThreadStats {
    // distinct per-cpu slot => this worker's counter bumps are
    // single-writer and the all-slot sum is exact (threads <= NCPU)
    pin_thread_cpu_slot(thread_idx);

    let ranks = opts.ranks.max(2);
    let nodes = opts.nodes.max(1);
    let mut comms = Vec::with_capacity(n_comms);
    for c in 0..n_comms {
        let mut comm = Communicator::new(Topology::nvlink_b300(ranks));
        comm.reseed(opts.seed ^ ((thread_idx as u64) << 32) ^ c as u64);
        comm.data_mode = DataMode::Sampled(4 << 10);
        comm.prewarm_all();
        comm.set_tuner(Some(Arc::new(BpfTunerPlugin(host.clone()))));
        comm.set_profiler(Some(Arc::new(BpfProfilerPlugin(host.clone()))));
        comms.push(comm);
    }
    let mut bufs: Vec<Vec<f32>> = (0..ranks).map(|r| vec![r as f32 + 1.0; 1 << 10]).collect();

    // multi-node: every communicator is a `nodes × ranks` cluster; each
    // gets NET_RAILS modeled RDMA rails with the verified net policy on
    // the send/recv path (PolicyTransport) and staggered fault epochs.
    let cluster = (nodes > 1).then(|| ClusterTopology::rails_b300(nodes, ranks, NET_RAILS));
    let mut rail_ports: Vec<Vec<PolicyTransport<FaultyTransport<RdmaModelTransport>>>> = comms
        .iter()
        .map(|comm| {
            let Some(cl) = cluster.as_ref() else { return Vec::new() };
            let hook = crate::host::bpf_net_op_hook(host.clone(), comm.comm_id());
            (0..NET_RAILS)
                .map(|r| {
                    let plan = if opts.fault {
                        FaultPlan { epoch_ops: 64, phase: r as u64, ..FaultPlan::default() }
                    } else {
                        // epoch 0 of the cycle is Healthy and u64::MAX
                        // ops never finish it: fault injection off
                        FaultPlan { epoch_ops: u64::MAX, phase: 0, ..FaultPlan::default() }
                    };
                    let rdma = RdmaModelTransport::loopback(r as u32, cl.rail);
                    let faulty = FaultyTransport::new(rdma, r as u32, plan);
                    let template = NetOp {
                        rail: r as u32,
                        rails: NET_RAILS as u32,
                        ..NetOp::default()
                    };
                    PolicyTransport::new(faulty, hook.clone(), template)
                })
                .collect()
        })
        .collect();
    let payload = [0x5au8; NET_SHARD];
    let mut recv_buf = [0u8; NET_SHARD];

    let mut rng = Rng::new(opts.seed.wrapping_mul(0x9e37).wrapping_add(thread_idx as u64));
    let mut stats = ThreadStats {
        thread: thread_idx,
        comms: n_comms,
        decision_ns: Vec::with_capacity(n_comms * ops_per_comm),
        ..Default::default()
    };
    for _ in 0..ops_per_comm {
        for (ci, comm) in comms.iter().enumerate() {
            // mixed collectives, log-uniform logical sizes 4 KiB..4 MiB
            let coll = match rng.below(100) {
                0..=59 => CollType::AllReduce,
                60..=84 => CollType::AllGather,
                _ => CollType::ReduceScatter,
            };
            let logical = (4usize << 10) << rng.below(11);
            let res = comm.run(coll, &mut bufs, logical);
            stats.ops += 1;
            stats.bytes_moved += res.stats.bytes_moved;
            stats.decision_ns.push(res.plugin_overhead_ns as f64);
            // torn-read check: the observed config must be exactly one
            // variant's marker tuple
            let tuple = (res.cfg.algo, res.cfg.proto, res.cfg.nchannels);
            match tuple {
                (Algo::Ring, Proto::Simple, 7) => stats.variant_a += 1,
                (Algo::Tree, Proto::Ll, 13) => stats.variant_b += 1,
                _ => stats.torn += 1,
            }

            // cross-node shard: pick the next rank round-robin, ship one
            // shard to the same-local rank one node over, starting on the
            // rail-optimized rail and failing over across rails on flaps.
            if let Some(cl) = cluster.as_ref() {
                let rank = stats.ops as usize % cl.n_ranks();
                let (node, local) = cl.locate(rank);
                let rail0 = cl.rail_for(rank);
                let peer = (((node + 1) % nodes) * cl.gpus_per_node + local) as u32;
                let ports = &mut rail_ports[ci];
                let mut sent = false;
                for attempt in 0..NET_RAILS {
                    let port = &mut ports[(rail0 + attempt) % NET_RAILS];
                    port.template.peer = peer;
                    port.template.node = node as u32;
                    match port.isend(&payload) {
                        Ok(()) => {
                            if attempt > 0 {
                                stats.net_retries += 1;
                            }
                            // drain the loopback echo; a flap here is an
                            // epoch event on the recv gate, not data loss
                            match port.irecv(&mut recv_buf) {
                                Ok(()) => {}
                                Err(NetError::LinkDown { .. }) => stats.net_flaps += 1,
                                Err(e) => panic!("net drain failed: {e}"),
                            }
                            sent = true;
                            break;
                        }
                        Err(NetError::LinkDown { .. }) => stats.net_flaps += 1,
                        Err(e) => panic!("net send failed: {e}"),
                    }
                }
                if !sent {
                    // every rail flapped at this op count; fault phases
                    // stagger per rail and no two consecutive epochs flap,
                    // so hammering one rail terminates within two epochs
                    let port = &mut ports[rail0];
                    for _ in 0..(2 * 64 + 2) {
                        match port.isend(&payload) {
                            Ok(()) => {
                                stats.net_retries += 1;
                                match port.irecv(&mut recv_buf) {
                                    Ok(()) => {}
                                    Err(NetError::LinkDown { .. }) => stats.net_flaps += 1,
                                    Err(e) => panic!("net drain failed: {e}"),
                                }
                                sent = true;
                                break;
                            }
                            Err(NetError::LinkDown { .. }) => stats.net_flaps += 1,
                            Err(e) => panic!("net send failed: {e}"),
                        }
                    }
                }
                if !sent {
                    stats.net_lost += 1;
                }
            }
        }
    }
    // harvest per-endpoint policy decisions and the modeled wire time
    for ports in &rail_ports {
        for p in ports {
            stats.net_ops += p.decisions;
            // clock_ns already folds in flushed straggler delays; add
            // only the injected delay not yet charged to a transfer
            stats.net_modeled_ns += p.inner.inner.clock_ns + p.inner.inner.extra_delay_ns;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(threads: usize, comms: usize, reload: Option<u64>) -> TrafficOpts {
        TrafficOpts {
            comms,
            threads,
            ops_per_comm: 400,
            reload_every_ms: reload,
            seed: 0x5eed,
            ranks: 2,
            nodes: 1,
            fault: false,
        }
    }

    fn cluster(threads: usize, comms: usize, reload: Option<u64>, nodes: usize) -> TrafficOpts {
        TrafficOpts { nodes, fault: true, ranks: 4, ..small(threads, comms, reload) }
    }

    #[test]
    fn traffic_single_thread_clean() {
        let rep = run_traffic(&small(1, 1, None));
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.total_ops, 400);
        assert_eq!(rep.total_decisions, 400);
        assert_eq!(rep.tuner_map_hits, 400);
        assert_eq!(rep.shared_map_hits, 400);
        assert_eq!(rep.prof_map_hits, 400);
        assert_eq!(
            rep.ring_drained + rep.ring_dropped,
            400,
            "event-stream conservation: drained {} dropped {}",
            rep.ring_drained,
            rep.ring_dropped
        );
        assert!(rep.decisions_per_sec > 0.0);
        assert!(rep.p99_decision_ns >= rep.p50_decision_ns);
        // no reloads requested: every decision saw variant A
        assert_eq!(rep.per_thread[0].variant_a, 400);
        assert_eq!(rep.per_thread[0].variant_b, 0);
    }

    #[test]
    fn traffic_multi_thread_with_reloads_clean() {
        let rep = run_traffic(&small(4, 4, Some(2)));
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.total_ops, 1600);
        assert_eq!(rep.total_decisions, 1600);
        assert_eq!(rep.tuner_map_hits, 1600);
        assert_eq!(rep.shared_map_hits, 1600);
        assert_eq!(rep.per_thread.len(), 4);
        for s in &rep.per_thread {
            assert_eq!(s.ops, 400);
            assert_eq!(s.torn, 0);
            assert_eq!(s.variant_a + s.variant_b, s.ops);
        }
    }

    /// The acceptance gate for the event stream: 8 worker threads with
    /// a reload storm active, and the ring conserves every record.
    /// Also the acceptance gate for BPF_ATOMIC contention: the shared
    /// (non-per-cpu) counter both variants bump with `lock add64` must
    /// equal the op total exactly — 8 threads of lock RMWs on one
    /// Array element across a reload storm lose nothing.
    #[test]
    fn traffic_eight_threads_reload_storm_ring_conserved() {
        let rep = run_traffic(&small(8, 8, Some(1)));
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.total_ops, 8 * 400);
        assert_eq!(rep.ring_drained + rep.ring_dropped, rep.total_ops);
        assert_eq!(
            rep.shared_map_hits, rep.total_ops,
            "sum(shared counter) == decisions under the reload storm"
        );
    }

    #[test]
    fn traffic_more_comms_than_threads() {
        let rep = run_traffic(&small(2, 6, None));
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.total_ops, 6 * 400);
        let per_thread_comms: Vec<usize> = rep.per_thread.iter().map(|s| s.comms).collect();
        assert_eq!(per_thread_comms, vec![3, 3]);
    }

    /// The reload storm must not leak retired programs (ties the
    /// bounded-retirement fix to the engine: 50+ reloads, then zero
    /// retained versions once quiescent).
    #[test]
    fn traffic_reload_storm_reclaims_programs() {
        let host = Arc::new(NcclBpfHost::new());
        host.install_asm(TUNER_VARIANT_A).unwrap();
        host.install_asm(PROFILER_COUNTER).unwrap();
        for i in 0..60 {
            let src = if i % 2 == 0 { TUNER_VARIANT_B } else { TUNER_VARIANT_A };
            host.install_asm(src).unwrap();
        }
        let rep = run_traffic_on(host.clone(), &small(2, 2, Some(1)));
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        host.reclaim_retired();
        let snap = host.snapshot();
        let retired: Vec<usize> = snap.hooks.iter().map(|h| h.retired).collect();
        assert_eq!(retired, vec![0, 0, 0], "retired programs must be reclaimed");
    }

    /// The stats acceptance gate: with per-program run stats enabled,
    /// the install ledger conserves every dispatch across an 8-thread
    /// reload storm — `sum(run_cnt) == decisions` even though the
    /// programs that served most of them were retired mid-run.
    #[test]
    fn traffic_reload_storm_conserves_run_stats() {
        let mut host = NcclBpfHost::new();
        host.set_load_options(crate::bpf::LoadOptions::new().stats(Some(true)));
        let host = Arc::new(host);
        install_traffic_policies(&host).unwrap();
        let rep = run_traffic_on(host.clone(), &small(8, 8, Some(1)));
        // run_traffic_on itself checks conservation when stats are on;
        // re-assert the invariant explicitly against the final snapshot
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        let snap = host.snapshot();
        assert!(snap.stats_enabled);
        assert_eq!(
            snap.hook_run_cnt(crate::bpf::ProgType::Tuner),
            host.decisions.load(Ordering::Relaxed),
            "tuner run_cnt conservation across the reload storm"
        );
        assert_eq!(
            snap.hook_run_cnt(crate::bpf::ProgType::Profiler),
            host.prof_events.load(Ordering::Relaxed),
            "profiler run_cnt conservation across the reload storm"
        );
        // the storm's swaps landed in the (bounded) reload journal
        assert!(!snap.journal.is_empty());
        assert!(snap.journal.len() <= crate::host::snapshot::JOURNAL_CAP);
        // attribution sanity: the run spent real time inside policies
        let tuner_total = snap.hook(crate::bpf::ProgType::Tuner).total_run;
        assert!(tuner_total.run_time_ns > 0);
        assert_eq!(tuner_total.error_cnt, 0);
    }

    /// Multi-node acceptance gate: 4 nodes with fault injection active
    /// and a reload storm swapping the net policy mid-flight — every
    /// policy decision is accounted (none lost across a failure epoch),
    /// the per-rail counters conserve, flaps were actually injected and
    /// every transfer eventually landed on some rail.
    #[test]
    fn traffic_four_nodes_fault_reload_storm_conserves_decisions() {
        let rep = run_traffic(&cluster(4, 4, Some(1), 4));
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.nodes, 4);
        assert!(rep.net_decisions > 0, "net datapath issued no decisions");
        assert_eq!(
            rep.net_events, rep.net_decisions,
            "every rail-policy consult must reach the verified program"
        );
        assert_eq!(
            rep.rail_map_hits, rep.net_decisions,
            "per-rail map counters must conserve across the reload storm"
        );
        // rails beyond NET_RAILS never see traffic
        for (r, &hits) in rep.rail_hits.iter().enumerate() {
            if r >= NET_RAILS {
                assert_eq!(hits, 0, "rail {} out of range got traffic", r);
            }
        }
        assert!(rep.net_flaps > 0, "fault plan injected no link flaps");
        assert!(rep.net_retries > 0, "flaps never forced a rail failover");
        assert_eq!(rep.net_lost, 0, "transfers lost: {}", rep.net_lost);
        // straggler epochs must show up on the modeled clock: 200us per
        // delayed op dwarfs the healthy per-op cost (~5us + wire)
        assert!(rep.net_modeled_ns > 0);
    }

    /// Without fault injection the same cluster runs clean: zero flaps,
    /// zero retries, zero lost, and the rail mapping spreads traffic
    /// over every rail.
    #[test]
    fn traffic_two_nodes_healthy_uses_all_rails() {
        let mut opts = cluster(2, 2, None, 2);
        opts.fault = false;
        let rep = run_traffic(&opts);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.net_flaps, 0);
        assert_eq!(rep.net_retries, 0);
        assert_eq!(rep.net_lost, 0);
        assert!(rep.net_decisions > 0);
        assert_eq!(rep.net_events, rep.net_decisions);
        assert_eq!(rep.rail_map_hits, rep.net_decisions);
        for r in 0..NET_RAILS {
            assert!(rep.rail_hits[r] > 0, "rail {} never used", r);
        }
    }

    /// Single-node runs must not touch the net datapath at all.
    #[test]
    fn traffic_single_node_has_no_net_traffic() {
        let rep = run_traffic(&small(1, 1, None));
        assert_eq!(rep.nodes, 1);
        assert_eq!(rep.net_decisions, 0);
        assert_eq!(rep.net_events, 0);
        assert_eq!(rep.rail_map_hits, 0);
    }
}
