//! Atomic policy hot-reload (§3 T3, §4 "Hot-reload mechanism").
//!
//! The active policy is an atomic pointer. Reload has three phases:
//! (1) verify the replacement, (2) compile it, (3) compare-and-swap the
//! pointer. Any in-flight call keeps executing the program it loaded
//! from the pointer; the next call picks up the new one. If
//! verification fails, the swap is aborted and the old policy continues
//! — the system never enters an unverified state.
//!
//! Reclamation: swapped-out programs are *retired*, not dropped, for
//! the lifetime of the slot (the paper retains the old pointer "until
//! in-flight calls drain"; retaining for the slot lifetime is the
//! degenerate-but-safe version — a policy object is a few KiB and
//! reloads are operator-initiated, so the retired list is small by
//! construction).

use crate::bpf::LoadedProgram;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One hot-swappable program slot (tuner / profiler / net each get one).
pub struct ReloadSlot {
    active: AtomicPtr<LoadedProgram>,
    /// keeps swapped-out programs alive (grace period = slot lifetime)
    retired: Mutex<Vec<Arc<LoadedProgram>>>,
    /// number of successful swaps
    pub swaps: AtomicU64,
    /// last swap's CAS latency in ns (phase 3 only — the hot-path cost)
    pub last_swap_ns: AtomicU64,
}

impl Default for ReloadSlot {
    fn default() -> Self {
        Self::new()
    }
}

impl ReloadSlot {
    pub fn new() -> ReloadSlot {
        ReloadSlot {
            active: AtomicPtr::new(std::ptr::null_mut()),
            retired: Mutex::new(Vec::new()),
            swaps: AtomicU64::new(0),
            last_swap_ns: AtomicU64::new(0),
        }
    }

    /// The currently active program, if any. Lock-free; this is on the
    /// per-decision hot path.
    #[inline]
    pub fn get(&self) -> Option<&LoadedProgram> {
        let p = self.active.load(Ordering::Acquire);
        if p.is_null() {
            None
        } else {
            // SAFETY: pointers stored in `active` come from Arcs held in
            // `retired` (or the live slot) and are never dropped while
            // the slot exists.
            Some(unsafe { &*p })
        }
    }

    /// Phase 3 of reload: atomically install `new` (verify+compile
    /// already happened while constructing the LoadedProgram). Returns
    /// the CAS latency in ns.
    pub fn swap(&self, new: Arc<LoadedProgram>) -> u64 {
        let new_ptr = Arc::as_ptr(&new) as *mut LoadedProgram;
        // keep the Arc alive before publishing the raw pointer
        self.retired.lock().unwrap().push(new);
        let t0 = std::time::Instant::now();
        // CAS loop (paper: "atomically swaps the function pointer via
        // compare-and-swap"); under concurrent reloaders last-wins.
        let mut cur = self.active.load(Ordering::Relaxed);
        loop {
            match self.active.compare_exchange_weak(
                cur,
                new_ptr,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        let ns = t0.elapsed().as_nanos() as u64;
        self.swaps.fetch_add(1, Ordering::Relaxed);
        self.last_swap_ns.store(ns, Ordering::Relaxed);
        ns
    }

    /// Deactivate (no policy). The old program is retained like any
    /// other retired program.
    pub fn clear(&self) {
        self.active.store(std::ptr::null_mut(), Ordering::Release);
    }

    /// Number of retired (still-alive) program versions.
    pub fn retired_count(&self) -> usize {
        self.retired.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpf::program::load_asm;
    use crate::bpf::MapRegistry;
    use crate::host::ctx::layouts;
    use std::sync::atomic::AtomicBool;

    fn prog(ret: i64) -> Arc<LoadedProgram> {
        let reg = MapRegistry::new();
        let src = format!("prog tuner p{}\n  mov64 r0, {}\n  exit\n", ret, ret);
        Arc::new(load_asm(&src, &reg, &layouts()).unwrap().remove(0))
    }

    #[test]
    fn empty_slot_returns_none() {
        let s = ReloadSlot::new();
        assert!(s.get().is_none());
    }

    #[test]
    fn swap_installs_and_retires() {
        let s = ReloadSlot::new();
        s.swap(prog(1));
        assert_eq!(s.get().unwrap().run(std::ptr::null_mut()), 1);
        s.swap(prog(2));
        assert_eq!(s.get().unwrap().run(std::ptr::null_mut()), 2);
        assert_eq!(s.swaps.load(Ordering::Relaxed), 2);
        assert_eq!(s.retired_count(), 2);
        s.clear();
        assert!(s.get().is_none());
    }

    #[test]
    fn swap_latency_is_recorded_and_small() {
        let s = ReloadSlot::new();
        let ns = s.swap(prog(7));
        assert!(ns > 0);
        assert!(ns < 1_000_000, "swap took {} ns", ns); // well under 1 ms
        assert_eq!(s.last_swap_ns.load(Ordering::Relaxed), ns);
    }

    /// The paper's §5.2 property in miniature: continuous invocations
    /// during concurrent reloads observe zero lost calls — every call
    /// sees either the old or the new policy, never a torn state.
    #[test]
    fn no_lost_calls_under_concurrent_reload() {
        let s = Arc::new(ReloadSlot::new());
        s.swap(prog(100));
        let stop = Arc::new(AtomicBool::new(false));

        let caller = {
            let s = s.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut calls = 0u64;
                let mut seen = std::collections::HashSet::new();
                while !stop.load(Ordering::Relaxed) {
                    let r = s.get().expect("policy must never vanish").run(std::ptr::null_mut());
                    assert!(r >= 100 && r < 200, "torn read: {}", r);
                    seen.insert(r);
                    calls += 1;
                }
                (calls, seen.len())
            })
        };

        for i in 101..150 {
            s.swap(prog(i));
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        stop.store(true, Ordering::Relaxed);
        let (calls, distinct) = caller.join().unwrap();
        assert!(calls > 0);
        assert!(distinct >= 1);
        assert_eq!(s.swaps.load(Ordering::Relaxed), 50);
    }
}
