//! Atomic policy hot-reload (§3 T3, §4 "Hot-reload mechanism").
//!
//! The active policy is an atomic pointer. Reload has three phases:
//! (1) verify the replacement, (2) compile it, (3) compare-and-swap the
//! pointer. Any in-flight call keeps executing the program it loaded
//! from the pointer; the next call picks up the new one. If
//! verification fails, the swap is aborted and the old policy continues
//! — the system never enters an unverified state.
//!
//! Reclamation: unpublished programs (swapped-out *or* cleared) are
//! *retired* into a bounded list and reclaimed once a quiescent point
//! is observed. Readers take a [`ProgGuard`] that bumps a per-thread
//! reader stripe; each retired program is tagged with the unpublish
//! epoch (a counter bumped by every swap and clear, *after* the
//! pointer store), and a reclaim pass that loads the epoch and then
//! sees every reader stripe at zero frees all entries with
//! `entry epoch <= loaded epoch`. That is safe because all of these
//! operations are SeqCst: if the reclaimer missed a reader's stripe
//! increment, that increment — and therefore the reader's subsequent
//! pointer load — comes after the reclaimer's stripe read, which comes
//! after its epoch load, which (for any entry it may free) comes after
//! the store that unpublished the entry; such a reader can only load
//! the currently-published pointer, never the retiree. Under
//! `--reload-every`-style continuous reload this keeps the retired
//! list O(1) instead of growing one program per swap forever.

use crate::bpf::LoadedProgram;
use std::ops::Deref;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Lock with poison recovery: a thread that panicked while holding one
/// of the slot's mutexes (a dying benchmark thread mid-install) must
/// not wedge every subsequent reload with a poisoned-mutex abort. The
/// guarded state stays consistent under poisoning: `current` holds an
/// Arc swap target and `retired` a retire list — both are valid at
/// every instruction boundary, so recovering the inner value is safe.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Number of reader-counter stripes. Readers pick a stripe by thread,
/// so concurrent dispatchers on different threads do not ping-pong one
/// cache line on the ns-scale hot path; the reclaimer checks them all.
const READER_STRIPES: usize = 8;

/// One stripe, padded to its own cache line.
#[repr(align(64))]
struct ReaderStripe(AtomicU64);

/// One hot-swappable program slot (tuner / profiler / net each get one).
pub struct ReloadSlot {
    active: AtomicPtr<LoadedProgram>,
    /// strong reference backing the published pointer
    current: Mutex<Option<Arc<LoadedProgram>>>,
    /// unpublished programs awaiting a quiescent point, tagged with the
    /// unpublish epoch (the value of `epoch` after the swap/clear that
    /// retired them)
    retired: Mutex<Vec<(u64, Arc<LoadedProgram>)>>,
    /// striped counters of readers currently holding a [`ProgGuard`]
    readers: [ReaderStripe; READER_STRIPES],
    /// unpublish events (swaps *and* clears) — the reclamation epoch.
    /// Every retire tags its entry with the post-increment value, so a
    /// reclaimer that can free the entry must have loaded `epoch` after
    /// the unpublishing store in the SeqCst total order.
    epoch: AtomicU64,
    /// number of successful swaps
    pub swaps: AtomicU64,
    /// last swap's CAS latency in ns (phase 3 only — the hot-path cost)
    pub last_swap_ns: AtomicU64,
}

/// A read guard for the active program. Holding it pins every retired
/// program version (reclamation observes the reader stripes); dropping
/// it re-arms reclamation. Dereferences to [`LoadedProgram`].
pub struct ProgGuard<'a> {
    stripe: &'a ReaderStripe,
    prog: &'a LoadedProgram,
}

impl Deref for ProgGuard<'_> {
    type Target = LoadedProgram;
    #[inline]
    fn deref(&self) -> &LoadedProgram {
        self.prog
    }
}

impl Drop for ProgGuard<'_> {
    #[inline]
    fn drop(&mut self) {
        self.stripe.0.fetch_sub(1, Ordering::Release);
    }
}

thread_local! {
    /// This thread's reader stripe index (assigned round-robin once).
    static STRIPE: usize = {
        static NEXT: AtomicU64 = AtomicU64::new(0);
        NEXT.fetch_add(1, Ordering::Relaxed) as usize % READER_STRIPES
    };
}

impl Default for ReloadSlot {
    fn default() -> Self {
        Self::new()
    }
}

impl ReloadSlot {
    /// An empty slot (no policy installed).
    pub fn new() -> ReloadSlot {
        ReloadSlot {
            active: AtomicPtr::new(std::ptr::null_mut()),
            current: Mutex::new(None),
            retired: Mutex::new(Vec::new()),
            readers: std::array::from_fn(|_| ReaderStripe(AtomicU64::new(0))),
            epoch: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            last_swap_ns: AtomicU64::new(0),
        }
    }

    /// The currently active program, if any. Lock-free (two atomic RMWs
    /// on a per-thread stripe); this is on the per-decision hot path.
    #[inline]
    pub fn get(&self) -> Option<ProgGuard<'_>> {
        let stripe = &self.readers[STRIPE.with(|s| *s)];
        // SeqCst: the increment must be ordered before the pointer load
        // in the global order the reclaimer participates in (see module
        // docs); Acquire alone would allow the reclaimer to miss us.
        stripe.0.fetch_add(1, Ordering::SeqCst);
        let p = self.active.load(Ordering::SeqCst);
        if p.is_null() {
            stripe.0.fetch_sub(1, Ordering::Release);
            None
        } else {
            // SAFETY: a non-null published pointer is backed by the Arc
            // in `current` or, once unpublished, by an entry in
            // `retired` that cannot be reclaimed while our stripe
            // increment is visible.
            Some(ProgGuard { stripe, prog: unsafe { &*p } })
        }
    }

    /// Phase 3 of reload: atomically install `new` (verify+compile
    /// already happened while constructing the LoadedProgram). Returns
    /// the CAS latency in ns.
    pub fn swap(&self, new: Arc<LoadedProgram>) -> u64 {
        let new_ptr = Arc::as_ptr(&new) as *mut LoadedProgram;
        // serialize swappers; readers never take this lock
        let mut cur = plock(&self.current);
        let t0 = std::time::Instant::now();
        // CAS loop (paper: "atomically swaps the function pointer via
        // compare-and-swap"); under concurrent reloaders last-wins.
        let mut seen = self.active.load(Ordering::Relaxed);
        loop {
            match self.active.compare_exchange_weak(
                seen,
                new_ptr,
                Ordering::SeqCst,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(p) => seen = p,
            }
        }
        let ns = t0.elapsed().as_nanos() as u64;
        // the epoch bump must come after the unpublishing CAS (program
        // order, both SeqCst): a reclaimer whose epoch load covers this
        // retire therefore also observed the CAS
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        self.swaps.fetch_add(1, Ordering::Relaxed);
        let prev = cur.replace(new);
        drop(cur);
        if let Some(old) = prev {
            plock(&self.retired).push((epoch, old));
        }
        self.last_swap_ns.store(ns, Ordering::Relaxed);
        self.try_reclaim();
        ns
    }

    /// Deactivate (no policy). The old program is retired like any
    /// swapped-out version. Clears bump the same unpublish epoch as
    /// swaps — tagging the retiree with a *stale* epoch would let a
    /// reclaimer that pre-loaded the epoch free it while a concurrent
    /// reader still holds it.
    pub fn clear(&self) {
        let mut cur = plock(&self.current);
        self.active.store(std::ptr::null_mut(), Ordering::SeqCst);
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        let prev = cur.take();
        drop(cur);
        if let Some(old) = prev {
            plock(&self.retired).push((epoch, old));
        }
        self.try_reclaim();
    }

    /// Free retired programs that no reader can still hold: if no
    /// guard is outstanding *now*, every program retired at or before
    /// the current unpublish epoch is unreachable (any later reader
    /// loads the currently published pointer). Returns how many were
    /// freed.
    pub fn try_reclaim(&self) -> usize {
        let quiescent_epoch = self.epoch.load(Ordering::SeqCst);
        if self.readers.iter().any(|s| s.0.load(Ordering::SeqCst) != 0) {
            return 0;
        }
        let mut retired = plock(&self.retired);
        let before = retired.len();
        retired.retain(|(e, _)| *e > quiescent_epoch);
        before - retired.len()
    }

    /// Number of retired (still-alive, not-yet-reclaimed) versions.
    pub fn retired_count(&self) -> usize {
        plock(&self.retired).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpf::program::load_asm;
    use crate::bpf::MapRegistry;
    use crate::host::ctx::layouts;
    use std::sync::atomic::AtomicBool;

    fn prog(ret: i64) -> Arc<LoadedProgram> {
        let reg = MapRegistry::new();
        let src = format!("prog tuner p{}\n  mov64 r0, {}\n  exit\n", ret, ret);
        Arc::new(load_asm(&src, &reg, &layouts()).unwrap().remove(0))
    }

    #[test]
    fn empty_slot_returns_none() {
        let s = ReloadSlot::new();
        assert!(s.get().is_none());
    }

    #[test]
    fn swap_installs_and_reclaims_when_quiescent() {
        let s = ReloadSlot::new();
        s.swap(prog(1));
        assert_eq!(s.get().unwrap().run(std::ptr::null_mut()), 1);
        s.swap(prog(2));
        assert_eq!(s.get().unwrap().run(std::ptr::null_mut()), 2);
        assert_eq!(s.swaps.load(Ordering::Relaxed), 2);
        // no reader was live across the second swap: the retired p1 was
        // reclaimed by the post-swap quiescence check
        assert_eq!(s.retired_count(), 0);
        s.clear();
        assert!(s.get().is_none());
    }

    /// The leak this PR fixes: continuous reload must not grow the
    /// retired list one program per swap.
    #[test]
    fn retired_list_stays_bounded_under_continuous_reload() {
        let s = ReloadSlot::new();
        for i in 0..200 {
            s.swap(prog(100 + (i % 50)));
            assert!(
                s.retired_count() <= 1,
                "retired list grew to {} after swap {}",
                s.retired_count(),
                i
            );
        }
        assert_eq!(s.retired_count(), 0);
    }

    /// A held guard must pin the program it reads even across swaps,
    /// and release reclamation when dropped.
    #[test]
    fn guard_blocks_reclaim_until_dropped() {
        let s = ReloadSlot::new();
        s.swap(prog(7));
        let g = s.get().unwrap();
        s.swap(prog(8));
        // the old program is retired but must survive: `g` still reads it
        assert_eq!(s.retired_count(), 1);
        assert_eq!(g.run(std::ptr::null_mut()), 7);
        drop(g);
        assert_eq!(s.try_reclaim(), 1);
        assert_eq!(s.retired_count(), 0);
        assert_eq!(s.get().unwrap().run(std::ptr::null_mut()), 8);
    }

    /// Regression for the clear-path epoch bug: `clear()` must tag the
    /// retiree with a *fresh* unpublish epoch, so a reclaimer that
    /// sampled the epoch before the clear can never free a program a
    /// live guard still dereferences.
    #[test]
    fn guard_survives_clear_and_reclaim() {
        let s = ReloadSlot::new();
        s.swap(prog(9));
        let g = s.get().unwrap();
        s.clear(); // unpublishes while `g` is held
        assert!(s.get().is_none());
        assert_eq!(s.retired_count(), 1);
        assert_eq!(g.run(std::ptr::null_mut()), 9, "guard must keep the program alive");
        assert_eq!(s.try_reclaim(), 0, "live reader must block reclamation");
        drop(g);
        assert_eq!(s.try_reclaim(), 1);
        assert_eq!(s.retired_count(), 0);
    }

    /// Satellite: a thread that panics while holding the install-path
    /// lock must not poison every subsequent reload. Before the
    /// poison-recovering locks, the second `swap` below aborted with
    /// `PoisonError`.
    #[test]
    fn poisoned_install_lock_recovers() {
        let s = Arc::new(ReloadSlot::new());
        s.swap(prog(101));
        let s2 = s.clone();
        let panicked = std::thread::spawn(move || {
            let _guard = s2.current.lock().unwrap();
            panic!("benchmark thread dies while holding the install path");
        })
        .join();
        assert!(panicked.is_err(), "helper thread must have panicked");
        // both mutexes: poison `retired` too via a guard held at panic
        let s3 = s.clone();
        let _ = std::thread::spawn(move || {
            let _guard = s3.retired.lock().unwrap();
            panic!("die holding the retire list");
        })
        .join();
        // reload still works end to end
        s.swap(prog(102));
        assert_eq!(s.get().unwrap().run(std::ptr::null_mut()), 102);
        s.clear();
        assert!(s.get().is_none());
        s.try_reclaim();
        assert_eq!(s.retired_count(), 0);
    }

    #[test]
    fn swap_latency_is_recorded_and_small() {
        let s = ReloadSlot::new();
        let ns = s.swap(prog(7));
        assert!(ns > 0);
        assert!(ns < 1_000_000, "swap took {} ns", ns); // well under 1 ms
        assert_eq!(s.last_swap_ns.load(Ordering::Relaxed), ns);
    }

    /// The paper's §5.2 property in miniature: continuous invocations
    /// during concurrent reloads observe zero lost calls — every call
    /// sees either the old or the new policy, never a torn state — and
    /// reclamation running underneath never frees a program a reader
    /// still holds.
    #[test]
    fn no_lost_calls_under_concurrent_reload() {
        let s = Arc::new(ReloadSlot::new());
        s.swap(prog(100));
        let stop = Arc::new(AtomicBool::new(false));

        let caller = {
            let s = s.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut calls = 0u64;
                let mut seen = std::collections::HashSet::new();
                while !stop.load(Ordering::Relaxed) {
                    let r = s.get().expect("policy must never vanish").run(std::ptr::null_mut());
                    assert!(r >= 100 && r < 200, "torn read: {}", r);
                    seen.insert(r);
                    calls += 1;
                }
                (calls, seen.len())
            })
        };

        for i in 101..150 {
            s.swap(prog(i));
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        stop.store(true, Ordering::Relaxed);
        let (calls, distinct) = caller.join().unwrap();
        assert!(calls > 0);
        assert!(distinct >= 1);
        assert_eq!(s.swaps.load(Ordering::Relaxed), 50);
        // quiescent now: everything retired must be reclaimable
        s.try_reclaim();
        assert_eq!(s.retired_count(), 0);
    }
}
