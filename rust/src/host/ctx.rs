//! Policy ABI: the context structs handed to eBPF programs (R1) and the
//! ctx layouts the verifier enforces over them.
//!
//! Field offsets are part of the ABI — the restricted-C headers in
//! `policies/` and the bpfc compiler's builtin `struct policy_context`
//! definitions must match these exactly (checked by `abi_offsets` tests
//! below and by bpfc's codegen tests).
//!
//! The input/output split implements §3.3: "The verifier ensures
//! policies only read input fields and write output fields."

use crate::bpf::{CtxLayout, CtxLayouts};
use crate::cc::{Algo, CollType, Proto};

/// Output value meaning "policy defers to the engine default".
pub const DEFER: u32 = u32::MAX;

/// Algorithm id exposed to policies: NCCL_ALGO_RING.
pub const ALGO_RING: u32 = 0;
/// Algorithm id exposed to policies: NCCL_ALGO_TREE.
pub const ALGO_TREE: u32 = 1;
/// Algorithm id exposed to policies: NCCL_ALGO_NVLS.
pub const ALGO_NVLS: u32 = 2;
/// Protocol id exposed to policies: NCCL_PROTO_LL.
pub const PROTO_LL: u32 = 0;
/// Protocol id exposed to policies: NCCL_PROTO_LL128.
pub const PROTO_LL128: u32 = 1;
/// Protocol id exposed to policies: NCCL_PROTO_SIMPLE.
pub const PROTO_SIMPLE: u32 = 2;

/// Tuner policy context. Bytes [0, 32) are read-only inputs; bytes
/// [32, 48) are write-only outputs.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PolicyContext {
    /// input (offset 0): collective type index
    pub coll_type: u32,
    /// padding (offset 4)
    pub _pad0: u32,
    /// input (offset 8): message size in bytes
    pub msg_size: u64,
    /// input (offset 16): communicator rank count
    pub nranks: u32,
    /// input (offset 20): folded communicator id
    pub comm_id: u32,
    /// input (offset 24): engine channel ceiling
    pub max_channels: u32,
    /// padding (offset 28)
    pub _pad1: u32,
    /// output (offset 32): preferred algorithm id, or [`DEFER`]
    pub algorithm: u32,
    /// output (offset 36): preferred protocol id, or [`DEFER`]
    pub protocol: u32,
    /// output (offset 40): requested channel count (0 = engine default)
    pub n_channels: u32,
    /// padding (offset 44)
    pub _pad2: u32,
}

/// Total byte size of [`PolicyContext`] (ABI).
pub const POLICY_CTX_SIZE: u32 = 48;
/// Byte offset where the write-only output fields start (ABI).
pub const POLICY_CTX_OUT_START: u32 = 32;

impl PolicyContext {
    /// A fresh context with all outputs deferred.
    pub fn new(coll: CollType, msg_size: u64, nranks: u32, comm_id: u32, max_channels: u32) -> Self {
        PolicyContext {
            coll_type: coll.index() as u32,
            _pad0: 0,
            msg_size,
            nranks,
            comm_id,
            max_channels,
            _pad1: 0,
            algorithm: DEFER,
            protocol: DEFER,
            n_channels: 0, // 0 = engine default
            _pad2: 0,
        }
    }

    /// Decode the algorithm output, if set to a valid id.
    pub fn algo_out(&self) -> Option<Algo> {
        match self.algorithm {
            ALGO_RING => Some(Algo::Ring),
            ALGO_TREE => Some(Algo::Tree),
            ALGO_NVLS => Some(Algo::Nvls),
            _ => None,
        }
    }

    /// Decode the protocol output, if set to a valid id.
    pub fn proto_out(&self) -> Option<Proto> {
        match self.protocol {
            PROTO_LL => Some(Proto::Ll),
            PROTO_LL128 => Some(Proto::Ll128),
            PROTO_SIMPLE => Some(Proto::Simple),
            _ => None,
        }
    }
}

/// Profiler event context (all read-only).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct ProfilerContext {
    /// (offset 0) folded communicator id
    pub comm_id: u32,
    /// (offset 4) collective type index
    pub coll_type: u32,
    /// (offset 8) message size in bytes
    pub msg_size: u64,
    /// (offset 16) observed collective latency
    pub latency_ns: u64,
    /// (offset 24) channels the collective ran with
    pub n_channels: u32,
    /// (offset 28) per-communicator sequence number
    pub seq: u32,
}

/// Total byte size of [`ProfilerContext`] (ABI).
pub const PROFILER_CTX_SIZE: u32 = 32;

/// Net-plugin hook context (all read-only). The first 24 bytes are the
/// original single-node ABI (comm_id / is_send / bytes / peer);
/// the rail fields extend it without moving any existing offset, so
/// policies compiled against the old layout keep verifying.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct NetContext {
    /// (offset 0) folded communicator id
    pub comm_id: u32,
    /// (offset 4) 1 for send, 0 for receive
    pub is_send: u32,
    /// (offset 8) transfer size in bytes
    pub bytes: u64,
    /// (offset 16) peer rank
    pub peer: u32,
    /// (offset 20) rail this operation rides (rail-optimized mapping)
    pub rail: u32,
    /// (offset 24) total rails available to the node
    pub rails: u32,
    /// (offset 28) node index of the issuing rank
    pub node: u32,
}

/// Total byte size of [`NetContext`] (ABI).
pub const NET_CTX_SIZE: u32 = 32;

/// `net` ctx field layout, `(name, offset, width)` — single source for
/// the docs generator's net-ctx table and the ABI test below.
pub const NET_CTX_FIELDS: [(&str, u32, u32); 7] = [
    ("comm_id", 0, 4),
    ("is_send", 4, 4),
    ("bytes", 8, 8),
    ("peer", 16, 4),
    ("rail", 20, 4),
    ("rails", 24, 4),
    ("node", 28, 4),
];

/// The ctx layouts the verifier enforces, per program type.
pub fn layouts() -> CtxLayouts {
    CtxLayouts {
        tuner: CtxLayout {
            size: POLICY_CTX_SIZE,
            read: vec![(0, POLICY_CTX_OUT_START)],
            write: vec![(POLICY_CTX_OUT_START, POLICY_CTX_SIZE - POLICY_CTX_OUT_START)],
        },
        profiler: CtxLayout {
            size: PROFILER_CTX_SIZE,
            read: vec![(0, PROFILER_CTX_SIZE)],
            write: vec![],
        },
        net: CtxLayout { size: NET_CTX_SIZE, read: vec![(0, NET_CTX_SIZE)], write: vec![] },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::mem::{offset_of, size_of};

    #[test]
    fn abi_offsets_policy_context() {
        assert_eq!(size_of::<PolicyContext>(), POLICY_CTX_SIZE as usize);
        assert_eq!(offset_of!(PolicyContext, coll_type), 0);
        assert_eq!(offset_of!(PolicyContext, msg_size), 8);
        assert_eq!(offset_of!(PolicyContext, nranks), 16);
        assert_eq!(offset_of!(PolicyContext, comm_id), 20);
        assert_eq!(offset_of!(PolicyContext, max_channels), 24);
        assert_eq!(offset_of!(PolicyContext, algorithm), 32);
        assert_eq!(offset_of!(PolicyContext, protocol), 36);
        assert_eq!(offset_of!(PolicyContext, n_channels), 40);
    }

    #[test]
    fn abi_offsets_profiler_context() {
        assert_eq!(size_of::<ProfilerContext>(), PROFILER_CTX_SIZE as usize);
        assert_eq!(offset_of!(ProfilerContext, comm_id), 0);
        assert_eq!(offset_of!(ProfilerContext, msg_size), 8);
        assert_eq!(offset_of!(ProfilerContext, latency_ns), 16);
        assert_eq!(offset_of!(ProfilerContext, n_channels), 24);
        assert_eq!(offset_of!(ProfilerContext, seq), 28);
    }

    #[test]
    fn abi_offsets_net_context() {
        assert_eq!(size_of::<NetContext>(), NET_CTX_SIZE as usize);
        assert_eq!(offset_of!(NetContext, bytes), 8);
        assert_eq!(offset_of!(NetContext, peer), 16);
        assert_eq!(offset_of!(NetContext, rail), 20);
        assert_eq!(offset_of!(NetContext, rails), 24);
        assert_eq!(offset_of!(NetContext, node), 28);
    }

    #[test]
    fn net_ctx_field_table_matches_struct() {
        // NET_CTX_FIELDS feeds the docs generator; it must agree with
        // the real struct offsets and tile the ctx without gaps.
        let offsets = [
            ("comm_id", offset_of!(NetContext, comm_id) as u32),
            ("is_send", offset_of!(NetContext, is_send) as u32),
            ("bytes", offset_of!(NetContext, bytes) as u32),
            ("peer", offset_of!(NetContext, peer) as u32),
            ("rail", offset_of!(NetContext, rail) as u32),
            ("rails", offset_of!(NetContext, rails) as u32),
            ("node", offset_of!(NetContext, node) as u32),
        ];
        assert_eq!(NET_CTX_FIELDS.len(), offsets.len());
        let mut end = 0;
        for (&(name, off, width), &(rname, roff)) in NET_CTX_FIELDS.iter().zip(offsets.iter()) {
            assert_eq!(name, rname);
            assert_eq!(off, roff, "{} offset", name);
            assert_eq!(off, end, "{} leaves a gap", name);
            end = off + width;
        }
        assert_eq!(end, NET_CTX_SIZE);
    }

    #[test]
    fn defaults_are_deferred() {
        let c = PolicyContext::new(CollType::AllReduce, 1024, 8, 1, 32);
        assert_eq!(c.algorithm, DEFER);
        assert_eq!(c.algo_out(), None);
        assert_eq!(c.proto_out(), None);
        assert_eq!(c.n_channels, 0);
    }

    #[test]
    fn output_decoding() {
        let mut c = PolicyContext::new(CollType::AllReduce, 1024, 8, 1, 32);
        c.algorithm = ALGO_RING;
        c.protocol = PROTO_LL128;
        assert_eq!(c.algo_out(), Some(Algo::Ring));
        assert_eq!(c.proto_out(), Some(Proto::Ll128));
        c.algorithm = 99; // semantically invalid: treated as defer
        assert_eq!(c.algo_out(), None);
    }

    #[test]
    fn layouts_enforce_io_split() {
        let l = layouts();
        assert!(l.tuner.can_read(8, 8)); // msg_size
        assert!(!l.tuner.can_write(8, 8)); // inputs are read-only
        assert!(l.tuner.can_write(32, 4)); // algorithm
        assert!(!l.tuner.can_read(32, 4)); // outputs are write-only
        assert!(l.profiler.can_read(16, 8));
        assert!(!l.profiler.can_write(0, 4));
        assert!(l.net.can_read(8, 8));
        assert!(l.net.can_read(20, 4)); // rail
        assert!(l.net.can_read(28, 4)); // node
        assert!(!l.net.can_write(20, 4)); // net ctx is read-only
    }
}
