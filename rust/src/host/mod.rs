//! NCCLbpf — the paper's artifact: a plugin host that registers as
//! tuner + profiler (+ net hook) with the collective engine and runs
//! *verified* eBPF policies at each hook, with typed shared maps and
//! atomic hot-reload. No engine sources are modified: everything goes
//! through the public plugin ABI in [`crate::cc::plugin`].
#![deny(missing_docs)]

pub mod ctx;
pub mod native;
pub mod policydir;
pub mod reload;
pub mod ringbuf;
pub mod snapshot;
pub mod traffic;

use crate::bpf::analysis;
use crate::bpf::{
    load, prog_array_update, LoadError, LoadOptions, LoadStats, LoadedProgram, Map, MapRegistry,
    Object, PrintkSink, ProgType, VerifierStats,
};
use crate::cc::net::{NetHook, NetOp, NetOpHook};
use crate::cc::plugin::{CollInfoArgs, CostTable, ProfilerEvent, ProfilerPlugin, TunerPlugin};
use ctx::{NetContext, PolicyContext, ProfilerContext};
use reload::{ProgGuard, ReloadSlot};
use snapshot::{
    HookRow, HostSnapshot, InstallLedger, JournalEntry, MapRow, ProgramRow, RingStats, HOOKS,
    JOURNAL_CAP,
};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Report of one load/reload (§4: total reload is ms-scale; only the
/// pointer swap is on the hot path).
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    /// (program name, type) installed
    pub programs: Vec<(String, ProgType)>,
    /// per-program verification-cost counters, in load order (the
    /// `ncclbpf verify --stats` rows)
    pub prog_stats: Vec<(String, VerifierStats)>,
    /// total verification time across the object's programs
    pub verify_ns: u64,
    /// total post-verification analysis time (cost gate + dead-code
    /// rewrite) across the object's programs
    pub analyze_ns: u64,
    /// total pre-decode + JIT time across the object's programs
    pub compile_ns: u64,
    /// per-slot CAS latencies
    pub swap_ns: Vec<u64>,
}

impl LoadReport {
    /// Full reload cost: verify + analyze + compile + every swap —
    /// the same decomposition the reload journal records, so
    /// `BENCH_hotreload.json` and `ncclbpf stats` agree on "load".
    pub fn total_ns(&self) -> u64 {
        self.verify_ns + self.analyze_ns + self.compile_ns + self.swap_ns.iter().sum::<u64>()
    }
}

/// The NCCLbpf plugin host.
pub struct NcclBpfHost {
    /// shared map namespace: the cross-plugin composability substrate
    pub maps: MapRegistry,
    tuner: ReloadSlot,
    profiler: ReloadSlot,
    net: ReloadSlot,
    /// host-owned `bpf_trace_printk` sink: every program installed into
    /// this host writes through it, so `ncclbpf trace` can interleave
    /// printk lines with ring events and tests can capture output
    /// without process-global stdio hacks
    printk: Arc<PrintkSink>,
    /// load-pipeline configuration applied to every install (verifier
    /// pruning/budget, JIT inlining); the sink field is always
    /// overridden with this host's own printk sink
    load_opts: LoadOptions,
    /// tuner decisions executed
    pub decisions: AtomicU64,
    /// profiler events executed
    pub prof_events: AtomicU64,
    /// net hook invocations
    pub net_events: AtomicU64,
    /// policies that wrote semantically invalid outputs (deferred)
    pub invalid_outputs: AtomicU64,
    /// bounded install ledger: every program this host installed, with
    /// a strong clone of its run-stat cell so counts survive retirement
    ledger: Mutex<InstallLedger>,
    /// bounded reload journal: the last [`JOURNAL_CAP`] hook swaps with
    /// their verify/analyze/compile/swap timing
    journal: Mutex<VecDeque<JournalEntry>>,
}

impl Default for NcclBpfHost {
    fn default() -> Self {
        Self::new()
    }
}

impl NcclBpfHost {
    /// A fresh host with empty hook slots and its own map namespace.
    pub fn new() -> NcclBpfHost {
        NcclBpfHost {
            maps: MapRegistry::new(),
            tuner: ReloadSlot::new(),
            profiler: ReloadSlot::new(),
            net: ReloadSlot::new(),
            printk: PrintkSink::stderr(),
            load_opts: LoadOptions::new(),
            decisions: AtomicU64::new(0),
            prof_events: AtomicU64::new(0),
            net_events: AtomicU64::new(0),
            invalid_outputs: AtomicU64::new(0),
            ledger: Mutex::new(InstallLedger::default()),
            journal: Mutex::new(VecDeque::new()),
        }
    }

    /// The host's `bpf_trace_printk` sink (rebindable at any time;
    /// already-installed programs pick the new target up immediately).
    pub fn printk_sink(&self) -> Arc<PrintkSink> {
        self.printk.clone()
    }

    /// Set the load-pipeline options applied to every subsequent
    /// install (verifier pruning/budget, JIT inlining, dead-code
    /// rewriting, cost gate). Environment overrides are parsed at the
    /// CLI edge (see [`crate::cli::env_verifier_prune`] /
    /// [`crate::cli::env_jit_inline`] / [`crate::cli::env_rewrite`])
    /// and threaded in here; the sink field is always overridden with
    /// the host's own printk sink. When no explicit
    /// [`LoadOptions::max_cost`] gate is configured, the host enforces
    /// the per-hook [`default_cost_budget`] instead.
    pub fn set_load_options(&mut self, opts: LoadOptions) {
        self.load_opts = opts;
    }

    /// Enforce the per-hook-type cost budgets on freshly loaded
    /// programs — the admission criterion that makes "predictable
    /// policy overhead" a load-time guarantee rather than a hope.
    /// Skipped when the caller configured an explicit
    /// [`LoadOptions::max_cost`] gate (that gate already ran inside
    /// [`load`]).
    fn enforce_budgets(&self, progs: &[LoadedProgram]) -> Result<(), LoadError> {
        if self.load_opts.max_cost.is_some() {
            return Ok(());
        }
        for p in progs {
            let budget = default_cost_budget(p.prog_type);
            if p.info.max_cost > budget {
                return Err(LoadError::Budget {
                    prog: p.name.clone(),
                    detail: analysis::budget_diagnostic(&p.info, budget),
                });
            }
        }
        Ok(())
    }

    /// [`LoadOptions`] for one install: the configured options with
    /// the host's printk sink bound in.
    fn install_opts(&self) -> LoadOptions {
        self.load_opts.clone().sink(Some(self.printk.clone()))
    }

    fn slot(&self, pt: ProgType) -> &ReloadSlot {
        match pt {
            ProgType::Tuner => &self.tuner,
            ProgType::Profiler => &self.profiler,
            ProgType::Net => &self.net,
        }
    }

    /// Load (or hot-reload) every program in `obj`: verify + compile
    /// first, swap atomically only on success. On any verification
    /// failure *nothing* is swapped — the old policies keep running
    /// ("the system never enters an unverified state", §4).
    pub fn install_object(&self, obj: &Object) -> Result<LoadReport, LoadError> {
        let progs = load(obj, &self.maps, &ctx::layouts(), &self.install_opts())?.programs;
        self.enforce_budgets(&progs)?;
        let mut report = LoadReport::default();
        for p in &progs {
            report.verify_ns += p.stats.verify_ns;
            report.analyze_ns += p.stats.analyze_ns;
            report.compile_ns += p.stats.compile_ns;
            report.prog_stats.push((p.name.clone(), p.verifier_stats()));
        }
        for p in progs {
            let pt = p.prog_type;
            let name = p.name.clone();
            let ns = self.install_program(Arc::new(p));
            report.swap_ns.push(ns);
            report.programs.push((name, pt));
        }
        Ok(report)
    }

    /// Assemble + install (tests, CLI).
    pub fn install_asm(&self, source: &str) -> Result<LoadReport, LoadError> {
        let obj = crate::bpf::asm::assemble(source)
            .map_err(|e| LoadError::Structural(e.to_string()))?;
        self.install_object(&obj)
    }

    /// Compile restricted C + install (the paper's authoring path).
    pub fn install_c(&self, source: &str) -> Result<LoadReport, LoadError> {
        let obj = crate::bpfc::compile(source)
            .map_err(|e| LoadError::Structural(e.to_string()))?;
        self.install_object(&obj)
    }

    /// Verify + compile every program in `obj` against this host's
    /// registry and sink WITHOUT installing anything — the first half
    /// of chain assembly (the programs go into a prog array, not into
    /// the hook slots).
    pub fn load_only(&self, obj: &Object) -> Result<Vec<Arc<LoadedProgram>>, LoadError> {
        let progs = load(obj, &self.maps, &ctx::layouts(), &self.install_opts())?.programs;
        self.enforce_budgets(&progs)?;
        Ok(progs.into_iter().map(Arc::new).collect())
    }

    /// Install one already-loaded program into its hook slot; returns
    /// the swap latency in ns. Every install lands in the ledger and
    /// the reload journal ([`NcclBpfHost::snapshot`]).
    pub fn install_program(&self, prog: Arc<LoadedProgram>) -> u64 {
        let pt = prog.prog_type;
        let old = self.active_name(pt);
        lock_plain(&self.ledger).record(&prog);
        let new = prog.name.clone();
        let LoadStats { verify_ns, analyze_ns, compile_ns } = prog.stats;
        let ns = self.slot(pt).swap(prog);
        let epoch = self.slot(pt).swaps.load(Ordering::Relaxed);
        let mut j = lock_plain(&self.journal);
        if j.len() >= JOURNAL_CAP {
            j.pop_front();
        }
        j.push_back(JournalEntry {
            epoch,
            hook: pt,
            old,
            new,
            verify_ns,
            analyze_ns,
            compile_ns,
            swap_ns: ns,
        });
        ns
    }

    /// Replace one slot of the named prog array with `prog` — the
    /// chain hot-swap: in-flight tail calls finish on the program they
    /// already resolved, the next dispatch lands on the new link, and
    /// no other slot (or the dispatcher) is disturbed.
    pub fn prog_array_set(
        &self,
        map: &str,
        index: u32,
        prog: &Arc<LoadedProgram>,
    ) -> Result<(), String> {
        let m = self
            .maps
            .by_name(map)
            .ok_or_else(|| format!("no map named '{}' in this host", map))?;
        prog_array_update(&m, index, prog)?;
        // chain links count as installs for the ledger (their run-stat
        // cells stay attributed even after the slot is re-pointed)
        lock_plain(&self.ledger).record(prog);
        Ok(())
    }

    /// Assemble a composable policy chain from one object: every
    /// program named in `links` is verified and installed into the
    /// named prog array at its slot; every *other* program (typically
    /// the dispatcher doing the `bpf_tail_call`) is installed into its
    /// hook slot. Verification failures install nothing.
    pub fn install_chain(
        &self,
        obj: &Object,
        array: &str,
        links: &[(&str, u32)],
    ) -> Result<LoadReport, LoadError> {
        let progs = self.load_only(obj)?;
        // every requested link must name a real program — a typo'd link
        // would otherwise silently land in the hook slot while its
        // chain slot stayed empty
        for (name, _) in links {
            if !progs.iter().any(|p| p.name == *name) {
                return Err(LoadError::Structural(format!(
                    "install_chain: no program named '{}' in the object (programs: {})",
                    name,
                    progs.iter().map(|p| p.name.as_str()).collect::<Vec<_>>().join(", ")
                )));
            }
        }
        let mut report = LoadReport::default();
        for p in &progs {
            report.verify_ns += p.stats.verify_ns;
            report.analyze_ns += p.stats.analyze_ns;
            report.compile_ns += p.stats.compile_ns;
            report.prog_stats.push((p.name.clone(), p.verifier_stats()));
        }
        for p in progs {
            let slot = links.iter().find(|(name, _)| *name == p.name).map(|&(_, i)| i);
            match slot {
                Some(index) => {
                    self.prog_array_set(array, index, &p).map_err(LoadError::Structural)?;
                    report.programs.push((p.name.clone(), p.prog_type));
                }
                None => {
                    let pt = p.prog_type;
                    let name = p.name.clone();
                    report.swap_ns.push(self.install_program(p));
                    report.programs.push((name, pt));
                }
            }
        }
        Ok(report)
    }

    /// Remove the policy for one hook.
    pub fn clear(&self, pt: ProgType) {
        self.slot(pt).clear();
    }

    /// Name of the policy currently installed for hook `pt`, if any.
    pub fn active_name(&self, pt: ProgType) -> Option<String> {
        self.slot(pt).get().map(|p| p.name.clone())
    }

    /// (swap count, last swap latency ns) for a hook. Prefer
    /// [`NcclBpfHost::snapshot`], which folds this into [`HookRow`]
    /// alongside the rest of the host's introspection surface.
    pub fn swap_stats(&self, pt: ProgType) -> (u64, u64) {
        let s = self.slot(pt);
        (s.swaps.load(Ordering::Relaxed), s.last_swap_ns.load(Ordering::Relaxed))
    }

    /// A shared map by name (host-side observability; the §5.3 case
    /// study reads `latency_map` this way).
    pub fn map(&self, name: &str) -> Option<Arc<Map>> {
        self.maps.by_name(name)
    }

    // -- tuner hook ----------------------------------------------------------

    /// Execute the tuner policy for one decision. This is THE hot path
    /// Table 1 measures. Returns true if a policy ran.
    #[inline]
    pub fn tuner_decide(
        &self,
        args: &CollInfoArgs,
        cost: &mut CostTable,
        nchannels: &mut u32,
    ) -> bool {
        let Some(prog) = self.tuner.get() else { return false };
        let mut pctx = PolicyContext::new(
            args.coll,
            args.nbytes as u64,
            args.nranks as u32,
            fold_comm_id(args.comm_id),
            args.max_channels,
        );
        prog.run(&mut pctx as *mut PolicyContext as *mut u8);
        self.decisions.fetch_add(1, Ordering::Relaxed);
        self.apply_outputs(&pctx, args, cost, nchannels);
        true
    }

    /// Translate policy outputs into cost-table entries (§4 "NCCL
    /// integration challenges"): the preferred combo gets cost 0;
    /// everything else keeps the engine's estimates so unavailable
    /// combinations fall back gracefully. Channel requests are clamped.
    #[inline]
    fn apply_outputs(
        &self,
        pctx: &PolicyContext,
        args: &CollInfoArgs,
        cost: &mut CostTable,
        nchannels: &mut u32,
    ) {
        match (pctx.algo_out(), pctx.proto_out()) {
            (Some(a), Some(p)) => cost.prefer(a, p),
            (Some(a), None) => {
                if pctx.protocol != ctx::DEFER {
                    self.invalid_outputs.fetch_add(1, Ordering::Relaxed);
                }
                // algorithm-only preference: pick that algorithm's
                // cheapest protocol per the engine estimates. The seed's
                // `partial_cmp().unwrap()` panicked on NaN; total_cmp is
                // panic-free but orders negative NaN *below* every real
                // cost, so NaN is first mapped to +inf — a NaN-cost
                // entry (0.0/0.0, inf−inf in a cost model) must never
                // beat a real one in either sign.
                let key = |p: crate::cc::Proto| {
                    let c = cost.get(a, p);
                    if c.is_nan() {
                        f32::INFINITY
                    } else {
                        c
                    }
                };
                let best = crate::cc::proto::ALL_PROTOS
                    .iter()
                    .min_by(|&&x, &&y| key(x).total_cmp(&key(y)))
                    .copied()
                    .unwrap();
                cost.prefer(a, best);
            }
            (None, _) => {
                if pctx.algorithm != ctx::DEFER {
                    // semantically invalid id: count and defer (the
                    // verifier guarantees memory safety, not semantics)
                    self.invalid_outputs.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        if pctx.n_channels > 0 {
            *nchannels = pctx.n_channels.min(args.max_channels);
        }
    }

    // -- profiler hook ---------------------------------------------------------

    /// Execute the profiler policy for one event.
    #[inline]
    pub fn profiler_handle(&self, ev: &ProfilerEvent) {
        let Some(prog) = self.profiler.get() else { return };
        if let ProfilerEvent::CollEnd { comm_id, seq, coll, nbytes, cfg, latency_ns, .. } = ev {
            let mut pctx = ProfilerContext {
                comm_id: fold_comm_id(*comm_id),
                coll_type: coll.index() as u32,
                msg_size: *nbytes as u64,
                latency_ns: *latency_ns,
                n_channels: cfg.nchannels,
                seq: *seq as u32,
            };
            prog.run(&mut pctx as *mut ProfilerContext as *mut u8);
            self.prof_events.fetch_add(1, Ordering::Relaxed);
        }
    }

    // -- net hook ----------------------------------------------------------------

    /// Execute the net policy for one transport operation (legacy
    /// single-node entry point: no rail identity). Delegates to
    /// [`NcclBpfHost::net_handle_op`] with rail 0 of 1 on node 0.
    #[inline]
    pub fn net_handle(&self, comm_id: u64, is_send: bool, bytes: usize, peer: usize) {
        let op = NetOp {
            is_send,
            bytes: bytes as u64,
            peer: peer as u32,
            rail: 0,
            rails: 1,
            node: 0,
        };
        self.net_handle_op(comm_id, &op);
    }

    /// Execute the net policy for one rail-aware transport operation.
    /// Returns the program's verdict (r0) — `rail_selector.c` returns
    /// the rail it steers the transfer onto — or `None` when no net
    /// policy is installed.
    #[inline]
    pub fn net_handle_op(&self, comm_id: u64, op: &NetOp) -> Option<u64> {
        let prog = self.net.get()?;
        let mut nctx = NetContext {
            comm_id: fold_comm_id(comm_id),
            is_send: op.is_send as u32,
            bytes: op.bytes,
            peer: op.peer,
            rail: op.rail,
            rails: op.rails,
            node: op.node,
        };
        let r0 = prog.run(&mut nctx as *mut NetContext as *mut u8);
        self.net_events.fetch_add(1, Ordering::Relaxed);
        Some(r0)
    }

    /// Measure one tuner decision's host-side latency (bench helper).
    #[inline]
    pub fn timed_decision(&self, args: &CollInfoArgs) -> u64 {
        let mut cost = CostTable::all_sentinel();
        let mut ch = 0u32;
        let t0 = Instant::now();
        self.tuner_decide(args, &mut cost, &mut ch);
        t0.elapsed().as_nanos() as u64
    }

    /// Direct access to the loaded tuner program (ablation benches).
    /// The guard pins retired program versions while held — drop it
    /// promptly on reload-heavy paths.
    pub fn tuner_program(&self) -> Option<ProgGuard<'_>> {
        self.tuner.get()
    }

    /// Reclaim retired program versions on every hook slot (the
    /// traffic engine calls this after a reload storm; swaps also
    /// reclaim opportunistically).
    pub fn reclaim_retired(&self) -> usize {
        self.tuner.try_reclaim() + self.profiler.try_reclaim() + self.net.try_reclaim()
    }

    /// Retired-but-unreclaimed program versions across all hook slots
    /// (observability for the reload-leak regression test). Prefer
    /// [`NcclBpfHost::snapshot`], which carries the same counts per
    /// [`HookRow`].
    pub fn retired_counts(&self) -> (usize, usize, usize) {
        (self.tuner.retired_count(), self.profiler.retired_count(), self.net.retired_count())
    }

    /// Whether programs this host installs record per-program run
    /// stats ([`LoadOptions::stats`] / `NCCLBPF_STATS`).
    pub fn stats_enabled(&self) -> bool {
        self.load_opts.stats.unwrap_or(false)
    }

    /// One host-wide introspection snapshot: installed programs (with
    /// run stats), per-map pressure, hook-slot lifecycle, the recent
    /// reload journal, and the host event counters — the shape behind
    /// `ncclbpf stats` / `ncclbpf top`. Counters are relaxed-read, so
    /// the snapshot is monotone per counter, not an atomic cut.
    pub fn snapshot(&self) -> HostSnapshot {
        let ledger = lock_plain(&self.ledger);
        let programs: Vec<ProgramRow> = ledger
            .entries
            .iter()
            .map(|e| ProgramRow {
                name: e.name.clone(),
                prog_type: e.prog_type,
                insns: e.insns,
                max_cost: e.max_cost,
                jitted: e.jitted,
                live: e.prog.upgrade().is_some(),
                inline_stats: e.inline_stats,
                run: e.cell.as_ref().map(|c| c.aggregate()).unwrap_or_default(),
            })
            .collect();
        let hooks = HOOKS
            .iter()
            .map(|&pt| {
                let (swaps, last_swap_ns) = self.swap_stats(pt);
                let i = snapshot::hook_idx(pt);
                HookRow {
                    hook: pt,
                    active: self.active_name(pt),
                    swaps,
                    last_swap_ns,
                    retired: self.slot(pt).retired_count(),
                    compacted_installs: ledger.retired_installs[i],
                    compacted_run: ledger.retired_run[i],
                    total_run: ledger.hook_run_stats(pt),
                }
            })
            .collect();
        drop(ledger);
        let mut maps: Vec<MapRow> = self
            .maps
            .names()
            .into_iter()
            .filter_map(|name| self.maps.by_name(&name))
            .map(|m| MapRow {
                name: m.def.name.clone(),
                kind: m.def.kind,
                id: m.id,
                entries: m.len(),
                max_entries: m.def.max_entries,
                pressure: m.pressure_stats(),
                ring: (m.def.kind == crate::bpf::MapKind::RingBuf).then(|| RingStats {
                    emitted: m.ringbuf_emitted(),
                    drained: m.ringbuf_drained(),
                    dropped: m.ringbuf_dropped(),
                    discarded: m.ringbuf_discarded(),
                    hiwater_bytes: m.ringbuf_hiwater(),
                }),
            })
            .collect();
        maps.sort_by_key(|m| m.id);
        let journal = lock_plain(&self.journal).iter().cloned().collect();
        HostSnapshot {
            programs,
            maps,
            hooks,
            journal,
            decisions: self.decisions.load(Ordering::Relaxed),
            prof_events: self.prof_events.load(Ordering::Relaxed),
            net_events: self.net_events.load(Ordering::Relaxed),
            invalid_outputs: self.invalid_outputs.load(Ordering::Relaxed),
            stats_enabled: self.stats_enabled(),
        }
    }
}

/// Poison-recovering lock (same policy as `host::reload`: a panicking
/// holder must not wedge the host's observability surface).
fn lock_plain<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Default per-hook worst-case cost budgets, in `analysis` cost units
/// (DESIGN.md §12). The tuner sits on the collective hot path and gets
/// the tightest budget; profiler and net hooks run off the decision
/// path. An explicit [`LoadOptions::max_cost`] replaces these.
pub fn default_cost_budget(pt: ProgType) -> u64 {
    match pt {
        ProgType::Tuner => 5_000,
        ProgType::Profiler => 10_000,
        ProgType::Net => 10_000,
    }
}

/// Fold a 64-bit comm id into the 32-bit ABI field.
#[inline]
pub fn fold_comm_id(id: u64) -> u32 {
    (id ^ (id >> 32)) as u32
}

// -- plugin adapters -----------------------------------------------------------

/// The host, registered as the engine's tuner plugin.
pub struct BpfTunerPlugin(pub Arc<NcclBpfHost>);

impl TunerPlugin for BpfTunerPlugin {
    fn name(&self) -> &str {
        "ncclbpf_tuner"
    }
    #[inline]
    fn get_coll_info(&self, args: &CollInfoArgs, cost: &mut CostTable, nchannels: &mut u32) {
        self.0.tuner_decide(args, cost, nchannels);
    }
}

/// The host, registered as the engine's profiler plugin.
pub struct BpfProfilerPlugin(pub Arc<NcclBpfHost>);

impl ProfilerPlugin for BpfProfilerPlugin {
    fn name(&self) -> &str {
        "ncclbpf_profiler"
    }
    #[inline]
    fn on_event(&self, ev: &ProfilerEvent) {
        self.0.profiler_handle(ev);
    }
}

/// A net-transport hook backed by the host's net program.
pub fn bpf_net_hook(host: Arc<NcclBpfHost>, comm_id: u64, peer: usize) -> NetHook {
    Arc::new(move |is_send, bytes| host.net_handle(comm_id, is_send, bytes, peer))
}

/// A rail-aware net hook backed by the host's net program: the
/// [`crate::cc::net::PolicyTransport`] datapath calls this per
/// isend/irecv with the full [`NetOp`] and receives the policy verdict.
pub fn bpf_net_op_hook(host: Arc<NcclBpfHost>, comm_id: u64) -> NetOpHook {
    Arc::new(move |op: &NetOp| host.net_handle_op(comm_id, op))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{Algo, CollConfig, CollType, Proto, MAX_CHANNELS};

    fn args(nbytes: usize) -> CollInfoArgs {
        CollInfoArgs {
            coll: CollType::AllReduce,
            nbytes,
            nranks: 8,
            comm_id: 0xdead_beef_1234,
            max_channels: MAX_CHANNELS,
        }
    }

    const SIZE_AWARE_ASM: &str = r#"
prog tuner size_aware
  ldxdw r2, [r1+8]        ; msg_size
  jgt   r2, 32768, big
  stw   [r1+32], 1        ; algorithm = TREE
  stw   [r1+36], 0        ; protocol = LL
  ja    done
big:
  stw   [r1+32], 0        ; algorithm = RING
  stw   [r1+36], 2        ; protocol = SIMPLE
done:
  stw   [r1+40], 16       ; n_channels
  mov64 r0, 0
  exit
"#;

    #[test]
    fn tuner_decision_translates_to_cost_table() {
        let host = NcclBpfHost::new();
        host.install_asm(SIZE_AWARE_ASM).unwrap();
        let mut cost = CostTable::all_sentinel();
        let mut ch = 0;
        assert!(host.tuner_decide(&args(1 << 20), &mut cost, &mut ch));
        assert_eq!(cost.argmin(), Some((Algo::Ring, Proto::Simple)));
        assert_eq!(ch, 16);
        let mut cost = CostTable::all_sentinel();
        host.tuner_decide(&args(8 << 10), &mut cost, &mut ch);
        assert_eq!(cost.argmin(), Some((Algo::Tree, Proto::Ll)));
        assert_eq!(host.decisions.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn install_reports_per_program_verifier_stats() {
        let host = NcclBpfHost::new();
        let rep = host.install_asm(SIZE_AWARE_ASM).unwrap();
        assert_eq!(rep.prog_stats.len(), 1);
        let (name, st) = &rep.prog_stats[0];
        assert_eq!(name, "size_aware");
        assert!(st.insns_processed > 0);
        assert!(st.verify_ns > 0);
    }

    #[test]
    fn no_policy_means_no_decision() {
        let host = NcclBpfHost::new();
        let mut cost = CostTable::all_sentinel();
        let mut ch = 0;
        assert!(!host.tuner_decide(&args(1024), &mut cost, &mut ch));
        assert_eq!(cost.argmin(), None);
    }

    #[test]
    fn invalid_output_counts_and_defers() {
        let host = NcclBpfHost::new();
        host.install_asm(
            "prog tuner bad_out\n  stw [r1+32], 9\n  stw [r1+36], 9\n  mov64 r0, 0\n  exit\n",
        )
        .unwrap();
        let mut cost = CostTable::all_sentinel();
        let mut ch = 0;
        host.tuner_decide(&args(1024), &mut cost, &mut ch);
        assert_eq!(cost.argmin(), None, "invalid ids must defer");
        assert_eq!(host.invalid_outputs.load(Ordering::Relaxed), 1);
    }

    /// Regression: a NaN cost-table entry (a cost model or future
    /// plugin can produce one) must not panic the algorithm-only
    /// output path — the seed's `partial_cmp().unwrap()` did.
    #[test]
    fn nan_cost_entry_does_not_panic_algorithm_only_policy() {
        let host = NcclBpfHost::new();
        // algorithm-only preference: protocol stays DEFER
        host.install_asm("prog tuner algo_only\n  stw [r1+32], 1\n  mov64 r0, 0\n  exit\n")
            .unwrap();
        // positive NaN (0x7FC00000)
        let mut cost = CostTable::all_sentinel();
        cost.set(Algo::Tree, Proto::Ll, 100.0);
        cost.set(Algo::Tree, Proto::Ll128, f32::NAN);
        cost.set(Algo::Tree, Proto::Simple, 50.0);
        let mut ch = 0;
        assert!(host.tuner_decide(&args(1024), &mut cost, &mut ch));
        // NaN never wins: the cheapest real protocol is preferred
        assert_eq!(cost.argmin(), Some((Algo::Tree, Proto::Simple)));
        // negative NaN (0xFFC00000 — what x86 SSE invalid ops produce):
        // total_cmp alone would rank it below -inf, i.e. "cheapest"
        let mut cost = CostTable::all_sentinel();
        cost.set(Algo::Tree, Proto::Ll, 100.0);
        cost.set(Algo::Tree, Proto::Ll128, -f32::NAN);
        cost.set(Algo::Tree, Proto::Simple, 50.0);
        assert!(host.tuner_decide(&args(1024), &mut cost, &mut ch));
        assert_eq!(cost.argmin(), Some((Algo::Tree, Proto::Simple)));
        assert_eq!(host.invalid_outputs.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn channel_clamp_applied() {
        let host = NcclBpfHost::new();
        host.install_asm(
            "prog tuner chans\n  stw [r1+32], 0\n  stw [r1+36], 2\n  stw [r1+40], 1000\n  mov64 r0, 0\n  exit\n",
        )
        .unwrap();
        let mut cost = CostTable::all_sentinel();
        let mut ch = 0;
        host.tuner_decide(&args(1024), &mut cost, &mut ch);
        assert_eq!(ch, MAX_CHANNELS);
    }

    #[test]
    fn unsafe_policy_rejected_old_policy_survives() {
        let host = NcclBpfHost::new();
        host.install_asm(SIZE_AWARE_ASM).unwrap();
        assert_eq!(host.active_name(ProgType::Tuner).unwrap(), "size_aware");
        // attempt to hot-reload a program that writes an input field
        let bad = "prog tuner evil\n  stw [r1+8], 0\n  mov64 r0, 0\n  exit\n";
        let err = host.install_asm(bad).unwrap_err();
        assert!(err.to_string().contains("read-only"), "{}", err);
        // old policy still active and functional
        assert_eq!(host.active_name(ProgType::Tuner).unwrap(), "size_aware");
        let mut cost = CostTable::all_sentinel();
        let mut ch = 0;
        assert!(host.tuner_decide(&args(1 << 20), &mut cost, &mut ch));
    }

    const RECORD_LATENCY_ASM: &str = r#"
map latency_map hash key=4 value=16 entries=64

prog profiler record_latency
  mov64 r6, r1
  ldxdw r7, [r6+16]       ; latency_ns
  ldxw  r8, [r6+24]       ; n_channels
  stw   [r10-4], 0        ; key = 0
  stxdw [r10-24], r7      ; value[0..8]  = latency
  stxdw [r10-16], r8      ; value[8..16] = channels
  mov64 r2, r10
  add64 r2, -4
  mov64 r3, r10
  add64 r3, -24
  mov64 r4, 0
  ldmap r1, latency_map
  call  bpf_map_update_elem
  mov64 r0, 0
  exit
"#;

    const ADAPTIVE_TUNER_ASM: &str = r#"
map latency_map hash key=4 value=16 entries=64

prog tuner adaptive
  mov64 r6, r1            ; save ctx (call clobbers r1-r5)
  stw   [r10-4], 0
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, latency_map
  call  bpf_map_lookup_elem
  jne   r0, 0, have
  stw   [r6+40], 4        ; no samples yet: conservative 4 channels
  mov64 r0, 0
  exit
have:
  ldxdw r3, [r0+0]        ; avg latency
  jgt   r3, 1000000, slow
  stw   [r6+40], 12
  mov64 r0, 0
  exit
slow:
  stw   [r6+40], 2
  mov64 r0, 0
  exit
"#;

    /// The paper's Listing 1 closed loop: the profiler writes latency
    /// into a shared map; the tuner reads it for adaptive channels.
    #[test]
    fn profiler_to_tuner_map_sharing() {
        let host = NcclBpfHost::new();
        host.install_asm(RECORD_LATENCY_ASM).unwrap();
        host.install_asm(ADAPTIVE_TUNER_ASM).unwrap();

        let mut cost = CostTable::all_sentinel();
        let mut ch = 0;
        // no samples yet -> conservative
        host.tuner_decide(&args(1 << 20), &mut cost, &mut ch);
        assert_eq!(ch, 4);

        // profiler observes a fast collective
        let ev = ProfilerEvent::CollEnd {
            comm_id: 1,
            seq: 0,
            coll: CollType::AllReduce,
            nbytes: 1 << 20,
            cfg: CollConfig::new(Algo::Ring, Proto::Simple, 8),
            ts_ns: 0,
            latency_ns: 400_000,
        };
        host.profiler_handle(&ev);
        host.tuner_decide(&args(1 << 20), &mut cost, &mut ch);
        assert_eq!(ch, 12, "fast latency should ramp channels");

        // profiler observes contention (10x latency spike)
        let ev = ProfilerEvent::CollEnd {
            comm_id: 1,
            seq: 1,
            coll: CollType::AllReduce,
            nbytes: 1 << 20,
            cfg: CollConfig::new(Algo::Ring, Proto::Simple, 12),
            ts_ns: 0,
            latency_ns: 4_000_000,
        };
        host.profiler_handle(&ev);
        host.tuner_decide(&args(1 << 20), &mut cost, &mut ch);
        assert_eq!(ch, 2, "contention should back off");
        assert_eq!(host.prof_events.load(Ordering::Relaxed), 2);
        // host-side observability of the shared map
        let m = host.map("latency_map").unwrap();
        assert_eq!(m.read_u64(0), Some(4_000_000));
    }

    #[test]
    fn net_hook_counts_via_map() {
        let host = Arc::new(NcclBpfHost::new());
        host.install_asm(
            r#"
map net_stats array key=4 value=16 entries=4

prog net count_bytes
  mov64 r6, r1
  stw   [r10-4], 0
  mov64 r2, r10
  add64 r2, -4
  ldmap r1, net_stats
  call  bpf_map_lookup_elem
  jne   r0, 0, have
  mov64 r0, 0
  exit
have:
  ldxdw r2, [r6+8]        ; bytes
  ldxdw r3, [r0+0]
  add64 r3, r2
  stxdw [r0+0], r3        ; total_bytes += bytes
  ldxdw r3, [r0+8]
  add64 r3, 1
  stxdw [r0+8], r3        ; ops += 1
  mov64 r0, 0
  exit
"#,
        )
        .unwrap();
        let hook = bpf_net_hook(host.clone(), 42, 1);
        hook(true, 1000);
        hook(false, 500);
        hook(true, 24);
        let m = host.map("net_stats").unwrap();
        assert_eq!(m.read_u64(0), Some(1524));
        let ops = m.read_value(&0u32.to_le_bytes()).unwrap();
        assert_eq!(u64::from_le_bytes(ops[8..16].try_into().unwrap()), 3);
        assert_eq!(host.net_events.load(Ordering::Relaxed), 3);
    }

    /// Rail-aware net path: the policy reads the new rail/rails/node
    /// ctx fields and its r0 verdict is surfaced to the caller.
    #[test]
    fn net_op_hook_reads_rail_fields_and_returns_verdict() {
        let host = Arc::new(NcclBpfHost::new());
        // verdict = rail + 10*node when rails > 1, else 99
        host.install_asm(
            r#"
prog net rail_echo
  ldxw  r2, [r1+24]       ; rails
  jgt   r2, 1, multi
  mov64 r0, 99
  exit
multi:
  ldxw  r0, [r1+20]       ; rail
  ldxw  r3, [r1+28]       ; node
  mul64 r3, 10
  add64 r0, r3
  exit
"#,
        )
        .unwrap();
        let op = NetOp { is_send: true, bytes: 4096, peer: 3, rail: 2, rails: 4, node: 1 };
        assert_eq!(host.net_handle_op(7, &op), Some(12));
        let hook = bpf_net_op_hook(host.clone(), 7);
        assert_eq!(hook(&NetOp { rail: 3, rails: 4, node: 0, ..op }), Some(3));
        // the legacy single-node entry point presents rails=1
        host.net_handle(7, true, 100, 0);
        assert_eq!(host.net_events.load(Ordering::Relaxed), 3);
    }

    /// Satellite: trace_printk output is routed through the host-owned
    /// sink, so tests capture it without process-global stdio capture.
    #[test]
    fn printk_routes_through_host_sink() {
        let host = NcclBpfHost::new();
        host.printk_sink().set_capture();
        host.install_asm(
            "prog profiler pk\n  stw [r10-8], 0x21746168\n  mov64 r1, r10\n  add64 r1, -8\n  \
             mov64 r2, 4\n  call bpf_trace_printk\n  mov64 r0, 0\n  exit\n",
        )
        .unwrap();
        let ev = ProfilerEvent::CollEnd {
            comm_id: 1,
            seq: 0,
            coll: CollType::AllReduce,
            nbytes: 1024,
            cfg: CollConfig::new(Algo::Ring, Proto::Simple, 4),
            ts_ns: 0,
            latency_ns: 1000,
        };
        host.profiler_handle(&ev);
        host.profiler_handle(&ev);
        assert_eq!(
            host.printk_sink().drain_captured(),
            vec!["hat!".to_string(), "hat!".to_string()],
            "printk lines must land in the host sink, not stderr"
        );
        // rebinding the sink affects already-installed programs
        host.printk_sink().set_stderr();
        host.printk_sink().set_capture();
        host.profiler_handle(&ev);
        assert_eq!(host.printk_sink().drain_captured().len(), 1);
    }

    /// A 3-link tail-call chain assembled through the host API: the
    /// dispatcher lives in the tuner slot, the per-range tuners in the
    /// prog array, and one link hot-swaps without touching the others.
    #[test]
    fn install_chain_dispatches_and_hot_swaps_links() {
        const CHAIN: &str = r#"
map chain progarray entries=4

prog tuner dispatcher
  mov64 r6, r1
  ldxdw r2, [r1+8]        ; msg_size
  mov64 r3, 0
  jle   r2, 32768, go     ; <=32KiB -> slot 0
  mov64 r3, 1
  jle   r2, 4194304, go   ; <=4MiB -> slot 1
  mov64 r3, 2
go:
  ldmap r2, chain
  call  bpf_tail_call
  stw   [r6+40], 4        ; fallthrough: conservative default
  mov64 r0, 0
  exit

prog tuner t_small
  stw   [r1+32], 1
  stw   [r1+36], 0
  stw   [r1+40], 16
  mov64 r0, 0
  exit

prog tuner t_mid
  stw   [r1+32], 0
  stw   [r1+36], 2
  stw   [r1+40], 16
  mov64 r0, 0
  exit

prog tuner t_large
  stw   [r1+32], 0
  stw   [r1+36], 2
  stw   [r1+40], 32
  mov64 r0, 0
  exit
"#;
        let host = NcclBpfHost::new();
        let obj = crate::bpf::asm::assemble(CHAIN).unwrap();
        let report = host
            .install_chain(&obj, "chain", &[("t_small", 0), ("t_mid", 1), ("t_large", 2)])
            .unwrap();
        assert_eq!(report.programs.len(), 4);
        assert_eq!(host.active_name(ProgType::Tuner).unwrap(), "dispatcher");

        let decide = |bytes: usize| {
            let mut cost = CostTable::all_sentinel();
            let mut ch = 0u32;
            assert!(host.tuner_decide(&args(bytes), &mut cost, &mut ch));
            (cost.argmin(), ch)
        };
        assert_eq!(decide(8 << 10), (Some((Algo::Tree, Proto::Ll)), 16));
        assert_eq!(decide(1 << 20), (Some((Algo::Ring, Proto::Simple)), 16));
        assert_eq!(decide(64 << 20), (Some((Algo::Ring, Proto::Simple)), 32));

        // hot-swap only the mid link: small/large keep dispatching
        let mid_v2 = Arc::new(
            crate::bpf::program::load_asm(
                "prog tuner t_mid_v2\n  stw [r1+32], 2\n  stw [r1+36], 2\n  \
                 stw [r1+40], 8\n  mov64 r0, 0\n  exit\n",
                &host.maps,
                &ctx::layouts(),
            )
            .unwrap()
            .remove(0),
        );
        host.prog_array_set("chain", 1, &mid_v2).unwrap();
        assert_eq!(decide(1 << 20), (Some((Algo::Nvls, Proto::Simple)), 8));
        assert_eq!(decide(8 << 10), (Some((Algo::Tree, Proto::Ll)), 16));
        assert_eq!(decide(64 << 20), (Some((Algo::Ring, Proto::Simple)), 32));

        // clearing a link degrades that range to the fallthrough path
        assert!(host.map("chain").unwrap().prog_array_clear(1));
        let (pref, ch) = decide(1 << 20);
        assert_eq!(pref, None, "fallthrough defers algo/proto");
        assert_eq!(ch, 4);

        // a typo'd link name is a hard error before anything installs,
        // never a silent misroute into the hook slot
        let err = host.install_chain(&obj, "chain", &[("tune_smal", 0)]).unwrap_err();
        assert!(err.to_string().contains("no program named"), "{}", err);
        assert_eq!(host.active_name(ProgType::Tuner).unwrap(), "dispatcher");
    }

    /// Satellite: [`LoadOptions`] set on the host reach the JIT — the
    /// same policy installs with call-site inlining on by default and
    /// falls back to trampolines when the toggle is off.
    #[test]
    fn load_options_inline_toggle_threads_through_host() {
        let run = |inline: Option<bool>| {
            let mut host = NcclBpfHost::new();
            host.set_load_options(LoadOptions::new().inline(inline));
            host.install_asm(ADAPTIVE_TUNER_ASM).unwrap();
            let mut cost = CostTable::all_sentinel();
            let mut ch = 0;
            assert!(host.tuner_decide(&args(1024), &mut cost, &mut ch));
            assert_eq!(ch, 4, "no samples yet: conservative default");
            host.tuner_program().unwrap().jit_inline_stats()
        };
        // None under NCCLBPF_NO_JIT — behavior above is still asserted
        if let (Some(on), Some(off)) = (run(None), run(Some(false))) {
            assert!(
                on.inlined_lookups + on.direct_calls > 0,
                "default install should inline the map lookup: {:?}",
                on
            );
            assert_eq!(off.inlined_lookups + off.direct_calls, 0, "{:?}", off);
            assert!(off.trampoline_calls > 0, "{:?}", off);
        }
    }

    /// Satellite: the host enforces per-hook cost budgets at install
    /// time with a diagnostic naming the hot path; an explicit
    /// `max_cost` gate replaces the default.
    #[test]
    fn cost_budget_gate_rejects_over_budget_tuner() {
        // ~2 units per lap x 3000 laps blows the 5000-unit tuner budget
        let blowout = "prog tuner hog\n  mov64 r1, 3000\nloop:\n  sub64 r1, 1\n  \
                       jne r1, 0, loop\n  mov64 r0, 0\n  exit\n";
        let host = NcclBpfHost::new();
        let err = host.install_asm(blowout).unwrap_err();
        assert!(err.to_string().contains("cost budget"), "{}", err);
        assert!(host.active_name(ProgType::Tuner).is_none(), "nothing installs");
        // an explicit (huge) max_cost gate replaces the default budget
        let mut host = NcclBpfHost::new();
        host.set_load_options(LoadOptions::new().max_cost(Some(u64::MAX)));
        host.install_asm(blowout).unwrap();
        assert_eq!(host.active_name(ProgType::Tuner).unwrap(), "hog");
    }

    /// Satellite: the rewrite toggle threads through the host like the
    /// prune/inline toggles, and decisions agree either way.
    #[test]
    fn load_options_rewrite_toggle_threads_through_host() {
        let dead = "prog tuner dead_arm\n  mov64 r2, 1\n  jne r2, 0, live\n  \
                    stw [r1+40], 2\nlive:\n  stw [r1+40], 6\n  mov64 r0, 0\n  exit\n";
        let run = |rewrite: Option<bool>| {
            let mut host = NcclBpfHost::new();
            host.set_load_options(LoadOptions::new().rewrite(rewrite));
            host.install_asm(dead).unwrap();
            let mut cost = CostTable::all_sentinel();
            let mut ch = 0;
            assert!(host.tuner_decide(&args(1024), &mut cost, &mut ch));
            assert_eq!(ch, 6, "the live arm decides");
            host.tuner_program().unwrap().rewrite_stats
        };
        let on = run(None).expect("the dead arm is rewritable");
        assert_eq!(on.wired_taken, 1);
        assert_eq!(on.removed_insns, 1);
        assert!(run(Some(false)).is_none(), "rewriting off: program as authored");
    }

    /// Tentpole: one snapshot covers programs (with run stats), maps
    /// (with pressure), hook lifecycle, and the reload journal — and
    /// run counts survive a hot reload (conservation).
    #[test]
    fn snapshot_covers_programs_maps_hooks_and_journal() {
        let mut host = NcclBpfHost::new();
        host.set_load_options(LoadOptions::new().stats(Some(true)));
        assert!(host.stats_enabled());
        host.install_asm(RECORD_LATENCY_ASM).unwrap();
        host.install_asm(ADAPTIVE_TUNER_ASM).unwrap();
        let mut cost = CostTable::all_sentinel();
        let mut ch = 0;
        for _ in 0..5 {
            host.tuner_decide(&args(1024), &mut cost, &mut ch);
        }
        // hot-reload the tuner, then keep deciding on the new program
        host.install_asm(SIZE_AWARE_ASM).unwrap();
        for _ in 0..3 {
            host.tuner_decide(&args(1024), &mut cost, &mut ch);
        }
        let snap = host.snapshot();
        assert!(snap.stats_enabled);
        assert_eq!(snap.decisions, 8);
        // conservation across the reload: retired adaptive's 5 runs +
        // live size_aware's 3 still sum to the decision count
        assert_eq!(snap.hook_run_cnt(ProgType::Tuner), 8);
        assert_eq!(snap.hook_run_cnt(ProgType::Profiler), 0);
        // every install is a program row; the tuner hook saw 2 swaps
        let names: Vec<&str> = snap.programs.iter().map(|p| p.name.as_str()).collect();
        for expect in ["record_latency", "adaptive", "size_aware"] {
            assert!(names.contains(&expect), "missing program row {expect}: {names:?}");
        }
        let th = snap.hook(ProgType::Tuner);
        assert_eq!(th.swaps, 2);
        assert_eq!(th.active.as_deref(), Some("size_aware"));
        // the shared map shows pressure from both hooks' operations
        let lm = snap.maps.iter().find(|m| m.name == "latency_map").unwrap();
        assert!(lm.pressure.lookups > 0, "{:?}", lm.pressure);
        assert!(lm.ring.is_none());
        // journal: oldest-first, epochs monotone per hook, phases timed
        assert_eq!(snap.journal.len(), 3);
        assert_eq!(snap.journal[2].new, "size_aware");
        assert_eq!(snap.journal[2].old.as_deref(), Some("adaptive"));
        assert!(snap.journal[2].verify_ns > 0);
        assert!(snap.journal[2].total_ns() >= snap.journal[2].verify_ns);
    }

    /// Satellite 6: `LoadReport::total_ns` includes the analyze phase,
    /// matching the journal's decomposition.
    #[test]
    fn load_report_total_includes_analyze_phase() {
        let host = NcclBpfHost::new();
        let rep = host.install_asm(SIZE_AWARE_ASM).unwrap();
        assert_eq!(
            rep.total_ns(),
            rep.verify_ns + rep.analyze_ns + rep.compile_ns + rep.swap_ns.iter().sum::<u64>()
        );
        let j = host.snapshot().journal;
        assert_eq!(j.len(), 1);
        assert_eq!(
            j[0].total_ns(),
            j[0].verify_ns + j[0].analyze_ns + j[0].compile_ns + j[0].swap_ns
        );
    }

    #[test]
    fn fold_comm_id_stable() {
        assert_eq!(fold_comm_id(7), fold_comm_id(7));
        assert_ne!(fold_comm_id(1), fold_comm_id(2u64 << 32));
        // high bits influence the folded id
        assert_ne!(fold_comm_id(0xaaaa_0000_0000), fold_comm_id(0xbbbb_0000_0000));
    }
}
