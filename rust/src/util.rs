//! Small self-contained utilities (no external crates are available
//! offline beyond the xla closure): PRNG, statistics, histograms, FNV
//! hashing, and human-readable size formatting.

/// xorshift128+ PRNG — deterministic, seedable (no `rand` crate offline).
#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // splitmix64 to spread the seed
        let mut z = seed.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut next = || {
            z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            x ^ (x >> 31)
        };
        let s0 = next();
        let s1 = next().max(1);
        Rng { s0, s1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// uniform in [0, n)
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.next_u64() % n
    }

    /// uniform f64 in [0, 1)
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// uniform f32 in [lo, hi)
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// standard normal via Box–Muller
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// FNV-1a 64-bit hash (used for communicator-id derivation, §4:
/// "deriving a stable ID from the context pointer via hashing").
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

pub fn fnv1a_u64(v: u64) -> u64 {
    fnv1a(&v.to_le_bytes())
}

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn of(xs: &[f64]) -> Stats {
        if xs.is_empty() {
            return Stats::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// coefficient of variation, in percent (paper §5.3 reports CV%).
    pub fn cv_percent(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            100.0 * self.std / self.mean
        }
    }
}

/// Percentile from a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Latency percentiles helper for ns samples (Table 1 reports P50/P99).
pub fn p50_p99(ns: &[f64]) -> (f64, f64) {
    (percentile(ns, 50.0), percentile(ns, 99.0))
}

/// Fixed-bucket log2 histogram for ns-scale latencies.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { buckets: vec![0; 64], count: 0, sum: 0 }
    }

    pub fn record(&mut self, v: u64) {
        let b = 64 - v.max(1).leading_zeros() as usize - 1;
        self.buckets[b.min(63)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded values (exact, unlike the bucketed
    /// quantiles) — the Prometheus `_sum` series.
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// The log2 bucket counts: `buckets()[i]` holds values in
    /// `[2^i, 2^(i+1))` — the Prometheus `_bucket` series source.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// approximate quantile from the log2 buckets (bucket midpoint).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << i) + (1u64 << i) / 2;
            }
        }
        1u64 << 63
    }
}

/// Parse sizes like "4M", "128K", "8G", "256" (bytes).
pub fn parse_size(s: &str) -> Result<usize, String> {
    let s = s.trim();
    let (num, mult) = match s.chars().last() {
        Some('K') | Some('k') => (&s[..s.len() - 1], 1usize << 10),
        Some('M') | Some('m') => (&s[..s.len() - 1], 1usize << 20),
        Some('G') | Some('g') => (&s[..s.len() - 1], 1usize << 30),
        _ => (s, 1),
    };
    num.trim()
        .parse::<usize>()
        .map(|v| v * mult)
        .map_err(|_| format!("bad size '{}'", s))
}

/// Format a byte count as a human string ("4 MiB").
pub fn fmt_size(bytes: usize) -> String {
    if bytes >= 1 << 30 && bytes % (1 << 30) == 0 {
        format!("{} GiB", bytes >> 30)
    } else if bytes >= 1 << 20 && bytes % (1 << 20) == 0 {
        format!("{} MiB", bytes >> 20)
    } else if bytes >= 1 << 10 && bytes % (1 << 10) == 0 {
        format!("{} KiB", bytes >> 10)
    } else {
        format!("{} B", bytes)
    }
}

/// Minimal JSON writer for results files (no serde offline).
pub struct JsonWriter {
    buf: String,
    stack: Vec<bool>, // true = need comma before next item
}

impl Default for JsonWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonWriter {
    pub fn new() -> JsonWriter {
        JsonWriter { buf: String::new(), stack: vec![] }
    }
    fn sep(&mut self) {
        if let Some(need) = self.stack.last_mut() {
            if *need {
                self.buf.push(',');
            }
            *need = true;
        }
    }
    pub fn begin_obj(&mut self) -> &mut Self {
        self.sep();
        self.buf.push('{');
        self.stack.push(false);
        self
    }
    pub fn end_obj(&mut self) -> &mut Self {
        self.buf.push('}');
        self.stack.pop();
        self
    }
    pub fn begin_arr(&mut self) -> &mut Self {
        self.sep();
        self.buf.push('[');
        self.stack.push(false);
        self
    }
    pub fn end_arr(&mut self) -> &mut Self {
        self.buf.push(']');
        self.stack.pop();
        self
    }
    pub fn key(&mut self, k: &str) -> &mut Self {
        self.sep();
        self.buf.push('"');
        self.buf.push_str(k);
        self.buf.push_str("\":");
        if let Some(need) = self.stack.last_mut() {
            *need = false;
        }
        self
    }
    pub fn str(&mut self, v: &str) -> &mut Self {
        self.sep();
        self.buf.push('"');
        for c in v.chars() {
            match c {
                '"' => self.buf.push_str("\\\""),
                '\\' => self.buf.push_str("\\\\"),
                '\n' => self.buf.push_str("\\n"),
                c => self.buf.push(c),
            }
        }
        self.buf.push('"');
        self
    }
    pub fn num(&mut self, v: f64) -> &mut Self {
        self.sep();
        if v.fract() == 0.0 && v.abs() < 1e15 {
            self.buf.push_str(&format!("{}", v as i64));
        } else {
            self.buf.push_str(&format!("{}", v));
        }
        self
    }
    pub fn finish(self) -> String {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic_and_spread() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        let mut c = Rng::new(8);
        assert_ne!(xs[0], c.next_u64());
        // below() respects the bound
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn rng_f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn stats_basics() {
        let s = Stats::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean - 5.0).abs() < 1e-9);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
        assert!(s.std > 0.0);
        assert!(s.cv_percent() > 0.0);
        assert_eq!(Stats::of(&[]).n, 0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert!((percentile(&xs, 99.0) - 99.0).abs() <= 1.0);
        let (p50, p99) = p50_p99(&xs);
        assert!(p50 < p99);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i);
        }
        assert_eq!(h.count(), 1000);
        let q50 = h.quantile(0.5);
        assert!(q50 >= 256 && q50 <= 1024, "q50={}", q50);
    }

    #[test]
    fn parse_and_format_sizes() {
        assert_eq!(parse_size("4M").unwrap(), 4 << 20);
        assert_eq!(parse_size("128K").unwrap(), 128 << 10);
        assert_eq!(parse_size("8G").unwrap(), 8usize << 30);
        assert_eq!(parse_size("77").unwrap(), 77);
        assert!(parse_size("x").is_err());
        assert_eq!(fmt_size(4 << 20), "4 MiB");
        assert_eq!(fmt_size(8 << 30), "8 GiB");
        assert_eq!(fmt_size(3), "3 B");
    }

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv1a(b"abc"), fnv1a(b"abc"));
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        assert_ne!(fnv1a_u64(1), fnv1a_u64(2));
    }

    #[test]
    fn json_writer_shapes() {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("name").str("t1");
        w.key("vals").begin_arr().num(1.0).num(2.5).end_arr();
        w.end_obj();
        assert_eq!(w.finish(), r#"{"name":"t1","vals":[1,2.5]}"#);
    }
}
