//! Generated reference documentation.
//!
//! `ncclbpf docs` renders `docs/REFERENCE.md` from the same in-source
//! tables the runtime executes against — [`helpers::HELPER_SPECS`] and
//! the per-type whitelists, [`MapKind`], the ctx layouts, the CLI
//! [`cli::SUBCOMMANDS`] table, and the §5.2 unsafe-program corpus — so
//! the reference can never silently drift from the code. CI
//! regenerates it (`ncclbpf docs --check docs/REFERENCE.md`) and fails
//! on any diff, and `committed_reference_is_in_sync` below is the same
//! gate as a plain `cargo test`.

use crate::bpf::helpers::{self, ArgType, ProgType, RetType};
use crate::bpf::maps::MapKind;
use crate::cc;
use crate::cli;
use crate::host::ctx;
use crate::host::policydir;
use std::fmt::Write as _;

/// Short name an argument class is documented under.
fn arg_name(a: ArgType) -> &'static str {
    match a {
        ArgType::ConstMapPtr => "map",
        ArgType::MapKey => "key_ptr",
        ArgType::MapValue => "value_ptr",
        ArgType::Scalar => "scalar",
        ArgType::MemLen => "mem_ptr",
        ArgType::ConstAllocSize => "const_size",
        ArgType::RingBufMem => "record_ptr",
        ArgType::Ctx => "ctx",
    }
}

/// Short name a return class is documented under.
fn ret_name(r: RetType) -> &'static str {
    match r {
        RetType::Scalar => "scalar",
        RetType::MapValueOrNull => "map_value_or_null",
        RetType::RingBufMemOrNull => "ringbuf_record_or_null",
    }
}

/// Every map kind with its documented operation surface, in kernel-id
/// order. The declaration syntax strings are what the assembler and
/// the restricted-C frontend actually parse.
fn map_kind_rows() -> Vec<(MapKind, &'static str, &'static str, &'static str)> {
    vec![
        (
            MapKind::Hash,
            "map NAME hash key=K value=V entries=N",
            "BPF_MAP(name, BPF_MAP_TYPE_HASH, K, V, N)",
            "lookup, update, delete",
        ),
        (
            MapKind::Array,
            "map NAME array value=V entries=N",
            "BPF_MAP(name, BPF_MAP_TYPE_ARRAY, __u32, V, N)",
            "lookup, update",
        ),
        (
            MapKind::ProgArray,
            "map NAME progarray entries=N",
            "BPF_PROG_ARRAY(name, N)",
            "bpf_tail_call (host side: prog_array_update / clear)",
        ),
        (
            MapKind::PerCpuArray,
            "map NAME percpu value=V entries=N",
            "BPF_MAP(name, BPF_MAP_TYPE_PERCPU_ARRAY, __u32, V, N)",
            "lookup, update (per-cpu slot)",
        ),
        (
            MapKind::RingBuf,
            "map NAME ringbuf entries=BYTES",
            "BPF_RINGBUF(name, BYTES)",
            "bpf_ringbuf_output / reserve / submit / discard / query",
        ),
    ]
}

/// Render the full `docs/REFERENCE.md` contents. Byte-stable for a
/// given source tree: the committed file must equal this string.
pub fn reference_markdown() -> String {
    let mut out = String::new();
    out.push_str("# NCCLbpf reference\n");
    out.push('\n');
    out.push_str("<!-- GENERATED FILE - do not edit by hand. -->\n");
    out.push_str("<!-- Regenerate: cargo run --release -- docs --out docs/REFERENCE.md -->\n");
    out.push_str("<!-- Drift gate: cargo run --release -- docs --check docs/REFERENCE.md -->\n");
    out.push('\n');
    out.push_str(
        "Rendered from the in-source tables the runtime executes against \
         (`helpers::HELPER_SPECS`, the per-type whitelists, `MapKind`, the ctx \
         layouts, `ctx::NET_CTX_FIELDS`, `cc::CLUSTER_PRESETS`, \
         `cli::SUBCOMMANDS`, `policydir::NET_POLICIES`, \
         `policydir::UNSAFE_POLICIES`, `policydir::STRESS_POLICIES`). CI \
         fails when this file drifts from the code.\n",
    );
    out.push('\n');

    out.push_str("## Program types\n");
    out.push('\n');
    out.push_str("| section | ctx size | readable ranges | writable ranges |\n");
    out.push_str("|---------|---------:|-----------------|----------------|\n");
    let layouts = ctx::layouts();
    for pt in ProgType::ALL {
        let l = layouts.for_type(pt);
        let fmt_ranges = |rs: &[(u32, u32)]| {
            if rs.is_empty() {
                "none".to_string()
            } else {
                rs.iter()
                    .map(|&(s, n)| format!("[{}, {})", s, s + n))
                    .collect::<Vec<_>>()
                    .join(", ")
            }
        };
        writeln!(
            out,
            "| `{}` | {} | {} | {} |",
            pt.section(),
            l.size,
            fmt_ranges(&l.read),
            fmt_ranges(&l.write)
        )
        .unwrap();
    }
    out.push('\n');

    out.push_str("## Net context fields\n");
    out.push('\n');
    writeln!(
        out,
        "Field layout of the {}-byte `net` ctx a policy reads on the \
         transport datapath (`ctx::NET_CTX_FIELDS`). The transport fills \
         one per transfer; the policy's return value is its verdict (for \
         the rail corpus, the rail to steer the transfer onto).",
        ctx::NET_CTX_SIZE
    )
    .unwrap();
    out.push('\n');
    out.push_str("| field | offset | width |\n");
    out.push_str("|-------|-------:|------:|\n");
    for (name, off, width) in ctx::NET_CTX_FIELDS {
        writeln!(out, "| `{}` | {} | {} |", name, off, width).unwrap();
    }
    out.push('\n');

    out.push_str("## Helper functions\n");
    out.push('\n');
    out.push_str(
        "Argument classes are what the verifier type-checks r1..r5 against; a \
         `mem_ptr` argument is followed by its byte length in the next \
         argument. The last three columns are the per-program-type \
         whitelists (calling a helper outside them is a load-time reject).\n",
    );
    out.push('\n');
    out.push_str("| id | helper | arguments | returns | tuner | profiler | net |\n");
    out.push_str("|---:|--------|-----------|---------|:-----:|:--------:|:---:|\n");
    for spec in helpers::HELPER_SPECS {
        let args = if spec.args.is_empty() {
            "(none)".to_string()
        } else {
            spec.args.iter().map(|&a| arg_name(a)).collect::<Vec<_>>().join(", ")
        };
        let mark = |pt: ProgType| if helpers::is_allowed(pt, spec.id) { "yes" } else { "-" };
        writeln!(
            out,
            "| {} | `{}` | {} | {} | {} | {} | {} |",
            spec.id,
            spec.name,
            args,
            ret_name(spec.ret),
            mark(ProgType::Tuner),
            mark(ProgType::Profiler),
            mark(ProgType::Net)
        )
        .unwrap();
    }
    out.push('\n');

    out.push_str("## Map kinds\n");
    out.push('\n');
    out.push_str("| kind | kernel id | asm declaration | restricted-C declaration | operations |\n");
    out.push_str("|------|----------:|-----------------|--------------------------|------------|\n");
    for (kind, asm, c, ops) in map_kind_rows() {
        writeln!(out, "| {:?} | {} | `{}` | `{}` | {} |", kind, kind.to_u32(), asm, c, ops)
            .unwrap();
    }
    out.push('\n');

    out.push_str("## Topology presets\n");
    out.push('\n');
    out.push_str(
        "Named hierarchical cluster shapes (`cc::CLUSTER_PRESETS`), built by \
         `cluster_preset` and swept by `ncclbpf bench` into \
         `BENCH_multinode.json`. Per-GPU rail GB/s is the node's aggregate \
         NIC injection bandwidth shared across its GPUs.\n",
    );
    out.push('\n');
    out.push_str("| preset | nodes | GPUs/node | rails | ranks | per-GPU rail GB/s | fabric |\n");
    out.push_str("|--------|------:|----------:|------:|------:|------------------:|--------|\n");
    for (name, ..) in cc::CLUSTER_PRESETS {
        let c = cc::cluster_preset(name).expect("preset");
        writeln!(
            out,
            "| `{}` | {} | {} | {} | {} | {:.1} | {} |",
            name,
            c.nodes,
            c.gpus_per_node,
            c.rails,
            c.n_ranks(),
            c.per_gpu_rail_gbps(),
            c.name
        )
        .unwrap();
    }
    out.push('\n');

    out.push_str("## CLI subcommands\n");
    out.push('\n');
    out.push_str("| subcommand | arguments | description |\n");
    out.push_str("|------------|-----------|-------------|\n");
    for (name, args, help) in cli::SUBCOMMANDS {
        // escape literal pipes so the markdown table stays a table
        let a = if args.is_empty() {
            "(none)".to_string()
        } else {
            args.replace('|', "\\|")
        };
        writeln!(out, "| `{}` | `{}` | {} |", name, a, help).unwrap();
    }
    out.push('\n');

    out.push_str("## Net policy corpus\n");
    out.push('\n');
    out.push_str(
        "Verified `net` policies under `rust/policies/` \
         (`policydir::NET_POLICIES`); the safety suite asserts each loads, \
         and the traffic engine and multinode bench run them on the \
         transport datapath.\n",
    );
    out.push('\n');
    out.push_str("| policy | what it does |\n");
    out.push_str("|--------|--------------|\n");
    for (name, what) in policydir::NET_POLICIES {
        writeln!(out, "| `{}` | {} |", name, what).unwrap();
    }
    out.push('\n');

    out.push_str("## Verifier rejection corpus\n");
    out.push('\n');
    out.push_str(
        "One unsafe program per bug class under `rust/policies/unsafe/`; the \
         safety suite asserts each is rejected at load time with the listed \
         needle in its error message.\n",
    );
    out.push('\n');
    out.push_str("| program | expected error contains |\n");
    out.push_str("|---------|-------------------------|\n");
    for (name, needle) in policydir::UNSAFE_POLICIES {
        writeln!(out, "| `{}` | `{}` |", name, needle).unwrap();
    }
    out.push('\n');

    out.push_str("## Verification stress corpus\n");
    out.push('\n');
    out.push_str(
        "Safe policies sized so exhaustive path enumeration exhausts the \
         verifier's complexity budget while state-equivalence pruning \
         verifies them with large headroom; `tests/verifier_pruning.rs` \
         asserts both directions and `BENCH_verifier.json` tracks their \
         cost.\n",
    );
    out.push('\n');
    out.push_str("| program | shape |\n");
    out.push_str("|---------|-------|\n");
    for (name, shape) in policydir::STRESS_POLICIES {
        writeln!(out, "| `{}` | {} |", name, shape).unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The drift gate (same check as `ncclbpf docs --check` in CI):
    /// the committed reference must be byte-identical to the generator
    /// output.
    #[test]
    fn committed_reference_is_in_sync() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/REFERENCE.md");
        let committed = std::fs::read_to_string(path)
            .expect("docs/REFERENCE.md must exist (run `ncclbpf docs --out docs/REFERENCE.md`)");
        assert_eq!(
            committed,
            reference_markdown(),
            "doc drift: regenerate with `cargo run --release -- docs --out docs/REFERENCE.md`"
        );
    }

    #[test]
    fn reference_covers_every_table() {
        let text = reference_markdown();
        for spec in helpers::HELPER_SPECS {
            assert!(text.contains(spec.name), "missing helper {}", spec.name);
        }
        for (name, _, _) in cli::SUBCOMMANDS {
            assert!(text.contains(&format!("`{}`", name)), "missing subcommand {}", name);
        }
        for (name, _) in policydir::UNSAFE_POLICIES {
            assert!(text.contains(name), "missing unsafe program {}", name);
        }
        for (name, _) in policydir::STRESS_POLICIES {
            assert!(text.contains(name), "missing stress policy {}", name);
        }
        for (name, _) in policydir::NET_POLICIES {
            assert!(text.contains(&format!("`{}`", name)), "missing net policy {}", name);
        }
        for (name, ..) in cc::CLUSTER_PRESETS {
            assert!(text.contains(&format!("`{}`", name)), "missing preset {}", name);
        }
        for (name, off, _) in ctx::NET_CTX_FIELDS {
            assert!(
                text.contains(&format!("| `{}` | {} |", name, off)),
                "missing net ctx field {}",
                name
            );
        }
        for (kind, ..) in map_kind_rows() {
            assert!(text.contains(&format!("{:?}", kind)), "missing map kind {:?}", kind);
        }
        assert!(text.contains("bpf_tail_call"));
    }
}
