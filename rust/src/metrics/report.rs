//! Bench-report serialization.
//!
//! Every `ncclbpf bench` run produces one [`BenchReport`] per
//! measurement (Table 1 overhead, Fig 2 sweep, hot-reload latency) and
//! writes it to `BENCH_<name>.json` in the chosen output directory
//! (repo root by convention), so each PR appends a point to the
//! performance trajectory. The JSON is flat and stable:
//!
//! ```json
//! {
//!   "schema": 1,
//!   "name": "table1_overhead",
//!   "created_unix": 1753600000,
//!   "git_sha": "abc123...",
//!   "machine": {"os": "linux", "arch": "x86_64", "ncpus": 8},
//!   "series": [
//!     {"label": "native_size_aware", "unit": "ns",
//!      "median": 21.0, "p99": 35.0, "mean": 22.4, "...": 0}
//!   ]
//! }
//! ```
//!
//! Serialized with [`crate::util::JsonWriter`] (no serde offline) and
//! parseable back with [`crate::runtime::manifest::parse_json`], which
//! is what `rust/tests/integration_cli.rs` does to validate the files.

use crate::util::JsonWriter;
use std::io;
use std::path::{Path, PathBuf};

/// One measured series: a table row or a sweep point.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub label: String,
    /// unit of `median` / `p99` / `mean` ("ns", "gbps", "us", ...)
    pub unit: String,
    pub median: f64,
    pub p99: f64,
    pub mean: f64,
    /// additional numeric facts (size_bytes, delta_vs_default_pct, ...)
    pub extra: Vec<(String, f64)>,
}

impl Series {
    pub fn new(label: impl Into<String>, unit: &str, median: f64, p99: f64, mean: f64) -> Series {
        Series {
            label: label.into(),
            unit: unit.to_string(),
            median,
            p99,
            mean,
            extra: Vec::new(),
        }
    }

    pub fn with(mut self, key: &str, value: f64) -> Series {
        self.extra.push((key.to_string(), value));
        self
    }
}

/// A complete benchmark report, ready to serialize.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub name: String,
    pub git_sha: String,
    pub created_unix: u64,
    /// (key, value) machine facts
    pub machine: Vec<(String, String)>,
    pub series: Vec<Series>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport {
            name: name.to_string(),
            git_sha: git_sha(),
            created_unix: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            machine: machine_facts(),
            series: Vec::new(),
        }
    }

    pub fn push(&mut self, s: Series) {
        self.series.push(s);
    }

    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("schema").num(1.0);
        w.key("name").str(&self.name);
        w.key("created_unix").num(self.created_unix as f64);
        w.key("git_sha").str(&self.git_sha);
        w.key("machine").begin_obj();
        for (k, v) in &self.machine {
            w.key(k).str(v);
        }
        w.end_obj();
        w.key("series").begin_arr();
        for s in &self.series {
            w.begin_obj();
            w.key("label").str(&s.label);
            w.key("unit").str(&s.unit);
            w.key("median").num(s.median);
            w.key("p99").num(s.p99);
            w.key("mean").num(s.mean);
            for (k, v) in &s.extra {
                w.key(k).num(*v);
            }
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
        w.finish()
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the file path.
    pub fn write_to(&self, dir: &Path) -> io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

fn machine_facts() -> Vec<(String, String)> {
    let ncpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    vec![
        ("os".to_string(), std::env::consts::OS.to_string()),
        ("arch".to_string(), std::env::consts::ARCH.to_string()),
        ("ncpus".to_string(), ncpus.to_string()),
    ]
}

/// Best-effort git sha: `git rev-parse HEAD` in the manifest dir, then
/// the GITHUB_SHA env (CI), then "unknown".
fn git_sha() -> String {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output();
    if let Ok(o) = out {
        if o.status.success() {
            if let Ok(s) = String::from_utf8(o.stdout) {
                let s = s.trim().to_string();
                if !s.is_empty() {
                    return s;
                }
            }
        }
    }
    std::env::var("GITHUB_SHA").unwrap_or_else(|_| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{parse_json, Json};

    fn sample() -> BenchReport {
        let mut r = BenchReport::new("unit_test");
        r.push(Series::new("row_a", "ns", 10.0, 20.5, 12.0).with("size_bytes", 4096.0));
        r.push(Series::new("row \"b\"", "gbps", 400.0, 410.0, 401.0));
        r
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let j = parse_json(&sample().to_json()).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("name").and_then(Json::as_str), Some("unit_test"));
        assert!(j.get("git_sha").and_then(Json::as_str).is_some());
        let machine = j.get("machine").unwrap();
        assert!(machine.get("os").and_then(Json::as_str).is_some());
        let series = j.get("series").and_then(Json::as_arr).unwrap();
        assert_eq!(series.len(), 2);
        let row = &series[0];
        assert_eq!(row.get("label").and_then(Json::as_str), Some("row_a"));
        assert_eq!(row.get("unit").and_then(Json::as_str), Some("ns"));
        assert_eq!(row.get("median").and_then(Json::as_u64), Some(10));
        assert!(row.get("p99").is_some());
        assert_eq!(row.get("size_bytes").and_then(Json::as_u64), Some(4096));
        // escaped label survives
        assert_eq!(series[1].get("label").and_then(Json::as_str), Some("row \"b\""));
    }

    #[test]
    fn write_to_creates_bench_file() {
        let dir = std::env::temp_dir().join("ncclbpf_report_test");
        let path = sample().write_to(&dir).unwrap();
        assert!(path.ends_with("BENCH_unit_test.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(parse_json(&text).is_ok());
    }
}
