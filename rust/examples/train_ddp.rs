//! END-TO-END driver (DESIGN.md §5): data-parallel training of the JAX
//! transformer where
//!   - per-rank forward/backward is the AOT `train_step` artifact
//!     (Layer 2 + the Layer-1 Pallas grad_scale kernel) run via PJRT,
//!   - gradient AllReduce flows through the collective engine with the
//!     verified eBPF size-aware policy making every tuner decision,
//!   - the fused-Adam Pallas artifact applies the update.
//!
//! Prereq: `make artifacts`. Run:
//!     cargo run --release --example train_ddp -- [steps] [ranks]
//!
//! The loss curve is printed for EXPERIMENTS.md.

use ncclbpf::cc::{Communicator, Topology};
use ncclbpf::host::{policydir, BpfProfilerPlugin, BpfTunerPlugin, NcclBpfHost};
use ncclbpf::runtime::{default_artifacts_dir, Runtime};
use ncclbpf::train::{DdpTrainer, TrainConfig};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let ranks: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let rt = Arc::new(Runtime::load(&default_artifacts_dir())?);
    println!(
        "model: {} params ({:.2} M), vocab {}, d_model {}, {} layers, seq {}, batch {}/rank",
        rt.manifest.n_params,
        rt.manifest.n_params as f64 / 1e6,
        rt.manifest.vocab,
        rt.manifest.d_model,
        rt.manifest.n_layers,
        rt.manifest.seq_len,
        rt.manifest.batch
    );

    // NCCLbpf host with the paper's case-study policy + profiler telemetry
    let host = Arc::new(NcclBpfHost::new());
    host.install_object(&policydir::build_named("nvlink_ring_mid_v2").unwrap())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    host.install_object(&policydir::build_named("record_latency").unwrap())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;

    let mut comm = Communicator::new(Topology::nvlink_b300(ranks));
    comm.set_tuner(Some(Arc::new(BpfTunerPlugin(host.clone()))));
    comm.set_profiler(Some(Arc::new(BpfProfilerPlugin(host.clone()))));

    let grad_bytes = rt.manifest.n_params_padded * 4;
    println!(
        "DDP: {} ranks, {} steps; per-step AllReduce of {:.2} MiB gradients \
         through the eBPF-tuned engine",
        ranks,
        steps,
        grad_bytes as f64 / (1 << 20) as f64
    );

    let cfg = TrainConfig { ranks, steps, log_every: 10, ..Default::default() };
    let mut trainer = DdpTrainer::new(rt.clone(), comm, cfg)?;
    let t0 = std::time::Instant::now();
    let report = trainer.train()?;
    let wall = t0.elapsed().as_secs_f64();

    println!();
    println!("== loss curve (step, loss, allreduce cfg) ==");
    for s in report.stats.iter().step_by((steps / 25).max(1)) {
        println!(
            "  {:4}  {:.4}  {}/{}/{}ch {:.0}us",
            s.step, s.loss, s.algo, s.proto, s.nchannels, s.allreduce_modeled_us
        );
    }
    let last = report.stats.last().unwrap();
    println!("  {:4}  {:.4}  (final)", last.step, last.loss);
    println!();
    println!(
        "loss {:.4} -> {:.4} over {} steps | {:.1} s wall ({:.0} ms/step)",
        report.first_loss(),
        report.last_loss(),
        steps,
        wall,
        wall * 1e3 / steps as f64
    );
    println!(
        "tuner decisions: {} | profiler events: {} | latency_map telemetry: {:?} ns",
        host.decisions.load(std::sync::atomic::Ordering::Relaxed),
        host.prof_events.load(std::sync::atomic::Ordering::Relaxed),
        host.map("latency_map")
            .and_then(|m| m.read_u64(ncclbpf::host::fold_comm_id(trainer.comm.comm_id()))),
    );
    anyhow::ensure!(
        report.last_loss() < report.first_loss(),
        "training must reduce the loss"
    );
    println!("E2E OK: L1 (Pallas kernels) + L2 (JAX model) + L3 (verified policies) compose.");
    Ok(())
}
