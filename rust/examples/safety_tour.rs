//! A tour of the verifier: every §5.2 bug class, its policy source, and
//! the load-time rejection — plus what the same bug does as a native
//! plugin (crash) for contrast.
//!
//!     cargo run --release --example safety_tour

use ncclbpf::host::{policydir, NcclBpfHost};

fn main() -> anyhow::Result<()> {
    let host = NcclBpfHost::new();

    println!("NCCLbpf verifier tour — 7 unsafe programs, one per bug class\n");
    for (name, class) in policydir::UNSAFE_POLICIES {
        let dir = policydir::policies_dir().join("unsafe");
        let path = ["c", "s"]
            .iter()
            .map(|e| dir.join(format!("{}.{}", name, e)))
            .find(|p| p.exists())
            .unwrap();
        let src = std::fs::read_to_string(&path)?;
        let buggy_line = src
            .lines()
            .find(|l| l.contains("BUG"))
            .unwrap_or("")
            .trim();
        println!("── {} ({})", name, class);
        println!("   source: {}", buggy_line);
        let obj = policydir::build_unsafe(name).map_err(|e| anyhow::anyhow!(e))?;
        match host.install_object(&obj) {
            Err(e) => println!("   {}", e),
            Ok(_) => anyhow::bail!("{} must be rejected", name),
        }
        println!();
    }

    println!("the same null-deref as a native plugin would be:");
    println!("   Signal: SIGSEGV (address 0x0) in getCollInfo() at native_bad_plugin.so");
    println!("   -> job crash, restart, minutes of lost training");
    println!("as an eBPF policy: rejected in microseconds, job never at risk.\n");

    println!("and the flip side — memory-safe but semantically bad policies load fine:");
    let rep = host
        .install_object(&policydir::build_named("bad_channels").unwrap())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    println!(
        "   bad_channels (forces 1 channel) ACCEPTED in {} us — the verifier\n\
         guarantees safety, not good decisions; semantic validation stays\n\
         with the operator (§5.3).",
        rep.total_ns() / 1000
    );
    Ok(())
}
