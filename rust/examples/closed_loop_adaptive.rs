//! Closed-loop adaptation (§5.3): two independently deployed eBPF
//! programs — a profiler and a tuner — cooperate through a shared typed
//! map to adapt the channel count to observed latency.
//!
//!     cargo run --release --example closed_loop_adaptive

use ncclbpf::cc::{CollType, Communicator, DataMode, Topology};
use ncclbpf::host::{fold_comm_id, policydir, BpfProfilerPlugin, BpfTunerPlugin, NcclBpfHost};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let host = Arc::new(NcclBpfHost::new());
    // deploy the two halves separately, as independent objects
    host.install_object(&policydir::build_named("record_latency").unwrap())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    host.install_object(&policydir::build_named("adaptive_channels").unwrap())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    println!(
        "deployed: profiler='{:?}' tuner='{:?}' sharing maps {:?}",
        host.active_name(ncclbpf::bpf::ProgType::Profiler),
        host.active_name(ncclbpf::bpf::ProgType::Tuner),
        host.maps.names()
    );

    let mut comm = Communicator::new(Topology::nvlink_b300(8));
    comm.data_mode = DataMode::Sampled(16 << 10);
    comm.prewarm_all();
    comm.set_tuner(Some(Arc::new(BpfTunerPlugin(host.clone()))));
    comm.set_profiler(Some(Arc::new(BpfProfilerPlugin(host.clone()))));
    let mut bufs: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0f32; 2048]).collect();
    let size = 16 << 20;

    println!("\nphase 1 — baseline: ramping up under healthy latency");
    for i in 0..40 {
        let r = comm.run(CollType::AllReduce, &mut bufs, size);
        if i % 8 == 0 || i == 39 {
            println!("  call {:>3}: {} channels, {:.0} us", i, r.cfg.nchannels, r.modeled_ns / 1e3);
        }
    }

    println!("\nphase 2 — contention: inject a 10x latency spike into the telemetry");
    let lm = host.map("latency_map").unwrap();
    let key = fold_comm_id(comm.comm_id());
    let mut v = lm.read_value(&key.to_le_bytes()).unwrap();
    let healthy = u64::from_le_bytes(v[..8].try_into().unwrap());
    v[..8].copy_from_slice(&(healthy * 10).to_le_bytes());
    lm.update(&key.to_le_bytes(), &v).unwrap();
    let r = comm.run(CollType::AllReduce, &mut bufs, size);
    println!("  next decision: {} channels (backed off)", r.cfg.nchannels);

    println!("\nphase 3 — recovery: profiler telemetry washes the spike out");
    for i in 0..40 {
        let r = comm.run(CollType::AllReduce, &mut bufs, size);
        if i % 8 == 0 || i == 39 {
            println!("  call {:>3}: {} channels", i, r.cfg.nchannels);
        }
    }

    println!(
        "\nfinal telemetry for comm {:#x}: avg latency {} ns",
        key,
        lm.read_u64(key).unwrap_or(0)
    );
    println!("closed loop OK: profiler -> shared map -> tuner, no engine changes.");
    Ok(())
}
