//! Quickstart: write a policy in restricted C, verify + install it into
//! the NCCLbpf host, attach the host to a communicator, and watch it
//! steer a collective.
//!
//!     cargo run --release --example quickstart

use ncclbpf::cc::{CollType, Communicator, DataMode, Topology};
use ncclbpf::host::{BpfTunerPlugin, NcclBpfHost};
use ncclbpf::util::fmt_size;
use std::sync::Arc;

const POLICY: &str = r#"
/* Prefer Ring/LL128 for mid-size AllReduce, defer otherwise. */
#define MIB (1024 * 1024)

SEC("tuner")
int my_first_policy(struct policy_context *ctx) {
    if (ctx->msg_size >= 4 * MIB && ctx->msg_size <= 128 * MIB) {
        ctx->algorithm = NCCL_ALGO_RING;
        ctx->protocol  = NCCL_PROTO_LL128;
        ctx->n_channels = 32;
    }
    return 0;
}
"#;

fn main() -> anyhow::Result<()> {
    // 1. the NCCLbpf host: compile (bpfc) + verify + JIT + install
    let host = Arc::new(NcclBpfHost::new());
    let report = host.install_c(POLICY).map_err(|e| anyhow::anyhow!(e.to_string()))?;
    println!(
        "installed '{}': verified in {} us, swapped in {} ns",
        report.programs[0].0,
        report.verify_ns / 1000,
        report.swap_ns[0]
    );

    // 2. an 8-GPU NVLink communicator with the host as its tuner plugin
    let mut comm = Communicator::new(Topology::nvlink_b300(8));
    comm.data_mode = DataMode::Sampled(1 << 20);
    comm.prewarm_all();
    comm.set_tuner(Some(Arc::new(BpfTunerPlugin(host.clone()))));

    // 3. run AllReduces and watch the policy steer them
    let mut bufs: Vec<Vec<f32>> = (0..8).map(|r| vec![(r + 1) as f32; 64 << 10]).collect();
    for size in [64 << 10, 8 << 20, 64 << 20, 512 << 20] {
        let res = comm.run(CollType::AllReduce, &mut bufs, size);
        println!(
            "AllReduce {:>8}: {:>4}/{:<6}/{:>2}ch -> {:>6.1} GB/s busbw (policy overhead {} ns)",
            fmt_size(size),
            res.cfg.algo.name(),
            res.cfg.proto.name(),
            res.cfg.nchannels,
            res.busbw_gbps,
            res.plugin_overhead_ns
        );
    }

    // 4. verification is a hard gate: a buggy policy cannot be installed
    let bad = r#"
struct v { __u64 x; };
BPF_MAP(m, BPF_MAP_TYPE_HASH, __u32, struct v, 4);
SEC("tuner")
int buggy(struct policy_context *ctx) {
    __u32 k = 0;
    struct v *p = bpf_map_lookup_elem(&m, &k);
    ctx->n_channels = (__u32) p->x;   /* missing null check */
    return 0;
}
"#;
    match host.install_c(bad) {
        Err(e) => println!("\nbuggy reload rejected as expected:\n  {}", e),
        Ok(_) => anyhow::bail!("unsafe policy must not load"),
    }
    println!("old policy still active: {:?}", host.active_name(ncclbpf::bpf::ProgType::Tuner));
    Ok(())
}
