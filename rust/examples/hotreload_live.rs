//! Live hot-reload: collectives run continuously on the main thread
//! while an "operator" thread rolls out policy updates — including a
//! broken one that the verifier bounces without any downtime.
//!
//!     cargo run --release --example hotreload_live

use ncclbpf::cc::{CollType, Communicator, DataMode, Topology};
use ncclbpf::host::{policydir, BpfTunerPlugin, NcclBpfHost};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let host = Arc::new(NcclBpfHost::new());
    host.install_object(&policydir::build_named("static_ring").unwrap())
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;

    let stop = Arc::new(AtomicBool::new(false));
    let operator = {
        let host = host.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let updates = [
                ("nvlink_ring_mid_v2", true),
                ("bad_channels", true),
                ("size_aware", true),
                ("nvlink_ring_mid_v2", true),
            ];
            for (name, _ok) in updates {
                std::thread::sleep(std::time::Duration::from_millis(40));
                let rep = host.install_object(&policydir::build_named(name).unwrap()).unwrap();
                eprintln!(
                    "[operator] hot-reloaded -> {:<20} (verify+compile {} us, swap {} ns)",
                    name,
                    (rep.verify_ns + rep.compile_ns) / 1000,
                    rep.swap_ns[0]
                );
            }
            // roll out a broken update: verification refuses it
            std::thread::sleep(std::time::Duration::from_millis(40));
            let bad = policydir::build_unsafe("unbounded_loop").unwrap();
            match host.install_object(&bad) {
                Err(e) => eprintln!("[operator] broken update bounced: {}", e),
                Ok(_) => panic!("unsafe policy must not load"),
            }
            std::thread::sleep(std::time::Duration::from_millis(40));
            stop.store(true, Ordering::Relaxed);
        })
    };

    // the data plane never stops
    let mut comm = Communicator::new(Topology::nvlink_b300(8));
    comm.data_mode = DataMode::Sampled(64 << 10);
    comm.prewarm_all();
    comm.set_tuner(Some(Arc::new(BpfTunerPlugin(host.clone()))));
    let mut bufs: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0f32; 4096]).collect();
    let mut calls = 0u64;
    let mut last_cfg = String::new();
    while !stop.load(Ordering::Relaxed) {
        let res = comm.run(CollType::AllReduce, &mut bufs, 64 << 20);
        calls += 1;
        let cfg = format!(
            "{}/{}/{}ch",
            res.cfg.algo.name(),
            res.cfg.proto.name(),
            res.cfg.nchannels
        );
        if cfg != last_cfg {
            println!(
                "[data plane] call {:>5}: config changed -> {:<20} ({:.0} GB/s)",
                calls, cfg, res.busbw_gbps
            );
            last_cfg = cfg;
        }
    }
    operator.join().unwrap();
    let swaps = host.snapshot().hook(ncclbpf::bpf::ProgType::Tuner).swaps;
    println!(
        "\n{} collectives executed across {} policy swaps with zero downtime",
        calls, swaps
    );
    Ok(())
}
